//! The [`SpectralPlan`]: plan once, execute many.
//!
//! A plan is created once per `(kernel, grid, stride, layout, solver,
//! threads)` configuration and captures everything that is invariant across
//! executions:
//!
//! - the **twiddle/phase tables** `e^{2πi·i·dy/n}`, `e^{2πi·j·dx/m}` for
//!   every (axis, tap-offset) pair — `O(n·kh + m·kw)` trig total, evaluated
//!   exactly once per plan instead of once per call;
//! - a **pool of per-worker workspaces** (symbol block, per-tap phases,
//!   Jacobi/Gram work matrices) so the per-frequency hot loop performs zero
//!   heap allocation;
//! - the **strided dual-grid geometry**: for stride `s > 1` the plan's
//!   frequency space is the coarse torus `(n/s)×(m/s)` and each block is the
//!   `c_out × s²·c_in` concatenation of the `s²` aliasing fine symbols;
//! - the **structured-convolution geometry**: grouped kernels make the
//!   per-frequency symbol *block-diagonal* — the plan solves `g`
//!   independent `(c_out/g) × s²·c_in` blocks per frequency instead of one
//!   `c_out × s²·c_in·g` matrix (an `O(g²)` cut in per-frequency SVD
//!   flops; depthwise degenerates to scalar symbols), dilation is folded
//!   into the phase tables at plan time (`e^{2πi⟨k, d·y⟩}` — zero marginal
//!   cost per frequency), and a transposed kernel solves the *forward*
//!   blocks (the adjoint symbol is their conjugate transpose, so the
//!   singular values are identical) and swaps the factor roles / shape
//!   metadata at packaging. See `docs/WORKLOADS.md` for the full matrix;
//! - the **folded execution domain** ([`crate::lfa::Fold`], on by
//!   default): real kernel weights give `A(−θ) = conj(A(θ))`, so full-grid
//!   executions solve only a canonical fundamental domain of `θ → −θ`
//!   (rows `0..=nc/2`, with the self-paired DC/Nyquist rows folded to
//!   columns `0..=mc/2` — each self-paired frequency solved exactly once)
//!   and mirror the conjugate half: singular values copied, `U`/`V`
//!   factors conjugated (with the stride aliasing permutation on `V`) —
//!   about a 2× cut in per-layer SVD work.
//!
//! One **request-driven sweep** then runs the fused symbol→SVD pipeline
//! over the dual grid: an internal driver owns frequency iteration,
//! fold/mirror bookkeeping, precision tiers, the escalation ladder and
//! workspace pooling, and emits every per-frequency result into a
//! pluggable [`SpectrumSink`] ([`super::sink`]). The public execute
//! surface is three thin entry points over it — [`SpectralPlan::execute`],
//! [`SpectralPlan::execute_topk`], [`SpectralPlan::execute_request_into`]
//! — plus the factor paths ([`SpectralPlan::full_svd`],
//! [`SpectralPlan::topk_svd`]), the custom-sink seam
//! ([`SpectralPlan::sweep_with`]) and the streaming density analytics
//! ([`SpectralPlan::density`]). Every SVD entry point in the crate —
//! `lfa::svd`, `lfa::stride`, the FFT baseline's SVD stage, the
//! coordinator's tiles — is a thin wrapper over this type.

use super::sink::{DensitySink, FactorAssembly, FullAssembly, SpectrumSink, TopKAssembly};
use super::workspace::{Workspace, WorkspacePool};
use super::{DensityRequest, SpectrumRequest};
use crate::conv::ConvKernel;
use crate::lfa::spectrum::{
    conj_factor, mirror_fill, FullSvd, SpectralDensity, Spectrum, SpectrumHealth, TopKSvd,
};
use crate::lfa::stride::alias_mirror_index;
use crate::lfa::svd::{BlockSolver, Fold, LfaOptions, Precision};
use crate::lfa::symbol::{scatter_shard, BlockLayout, SymbolGrid};
use crate::linalg::jacobi_svd;
use crate::linalg::power::TopKOptions;
use crate::linalg::SolveCert;
use crate::numeric::{C32, C64, CMat, SimdReal};
use std::f64::consts::PI;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Outcome of a partial-spectrum execution: the top-k values per frequency
/// plus the solver effort spent producing them.
#[derive(Clone, Debug)]
pub struct TopKResult {
    /// Partial spectrum (`per_freq == k`, descending per frequency).
    pub spectrum: Spectrum,
    /// Total solver iteration steps (Krylov steps plus completion-probe
    /// power steps) across all frequencies — the direct
    /// measure of how much the warm starts saved (compare a warm-sweep run
    /// against a cold one, [`SweepOptions::cold`]).
    pub iterations: u64,
}

impl TopKResult {
    /// Mean solver iteration steps per frequency.
    pub fn iterations_per_freq(&self) -> f64 {
        let freqs = (self.spectrum.n * self.spectrum.m).max(1);
        self.iterations as f64 / freqs as f64
    }
}

/// Knobs of a request-driven execution
/// ([`SpectralPlan::execute_request_into`]): worker count and warm-start
/// policy. `Default` is the plan's own effective thread count with
/// warm-started Krylov sweeps — what [`SpectralPlan::execute`] and
/// [`SpectralPlan::execute_topk`] use.
#[derive(Clone, Copy, Debug, Default)]
pub struct SweepOptions {
    /// Worker override: `None` uses the plan's
    /// [`SpectralPlan::effective_threads`], `Some(0)` resolves to
    /// `available_parallelism`, `Some(t)` is taken literally.
    pub threads: Option<usize>,
    /// Cold-start the Krylov solver at **every** frequency instead of
    /// carrying the warm basis along the sweep — the ablation that
    /// measures what cross-frequency warm-starting buys. Ignored by
    /// `Full` requests (the fused Jacobi path carries no basis).
    pub cold_start: bool,
}

impl SweepOptions {
    /// Explicit worker count (0 = auto), warm sweeps.
    pub fn with_threads(threads: usize) -> Self {
        Self { threads: Some(threads), cold_start: false }
    }

    /// Cold-start every frequency (the warm-start ablation), at the
    /// plan's own thread count.
    pub fn cold() -> Self {
        Self { threads: None, cold_start: true }
    }
}

/// Convergence verdict of one frequency's solve, after the escalation
/// ladder ran: the per-frequency unit [`SpectrumHealth`] aggregates.
/// Grouped kernels merge their per-group verdicts into one (a frequency is
/// degraded if *any* of its diagonal blocks is).
#[derive(Clone, Copy, Debug)]
struct FreqVerdict {
    /// Every solve (after any retry/escalation) met its tolerance.
    converged: bool,
    /// At least one solve needed a fresh-rotation restart or an
    /// escalation rung to get there.
    retried: bool,
    /// Escalation rungs taken (full-Jacobi / f64 re-solves).
    escalations: u64,
    /// Worst relative residual the accepted solves reported.
    residual: f64,
}

impl FreqVerdict {
    fn from_cert(cert: SolveCert) -> Self {
        Self {
            converged: cert.converged,
            retried: cert.restarted,
            escalations: 0,
            residual: cert.residual,
        }
    }

    /// Fold another group's verdict into this frequency's.
    fn absorb(&mut self, other: Self) {
        self.converged &= other.converged;
        self.retried |= other.retried;
        self.escalations += other.escalations;
        self.residual = self.residual.max(other.residual);
    }

    /// Record this frequency in a sweep-level health aggregate.
    fn record(self, health: &mut SpectrumHealth) {
        health.absorb(self.converged, self.retried, self.escalations, self.residual);
    }
}

/// Candidate-triplet scratch for the grouped factor sweep: per-group
/// top-k values and vectors are gathered here before the global top-k is
/// embedded into the block-diagonal factor matrices. Allocated once per
/// [`SpectralPlan::topk_svd`] call (a factor path — the
/// output allocates anyway), only for `groups > 1`.
struct FactorScratch {
    /// `g·kg` candidate singular values, group-major.
    vals: Vec<f64>,
    /// Candidate indices sorted by value, reused across frequencies.
    order: Vec<usize>,
    /// Per-group left vectors, `block_rows × g·kg`.
    u: CMat,
    /// Per-group right vectors, `block_cols × g·kg`.
    v: CMat,
}

/// A planned, reusable symbol→SVD execution for one convolution layer.
pub struct SpectralPlan {
    kernel: ConvKernel,
    /// Fine input grid.
    n: usize,
    m: usize,
    stride: usize,
    layout: BlockLayout,
    solver: BlockSolver,
    threads: usize,
    /// Coarse (output) dual grid: `n/stride × m/stride`.
    nc: usize,
    mc: usize,
    /// Per-frequency **solved** block shape: `(c_out/groups) ×
    /// stride²·c_in` — the shape of one group's diagonal block (the whole
    /// symbol for dense kernels, where `groups == 1`).
    block_rows: usize,
    block_cols: usize,
    /// Singular values per frequency of the whole (block-diagonal)
    /// operator: `groups · min(block_rows, block_cols)`.
    rank: usize,
    /// Conjugate-pair frequency folding: when set, full-grid executions
    /// solve only the fundamental domain of `θ → −θ` (rows `0..=nc/2`,
    /// self-paired rows folded to columns `0..=mc/2`) and mirror the rest
    /// — valid because the kernel weights are real (`A(−θ) = conj(A(θ))`).
    fold: bool,
    /// Scalar width the sweeps execute at ([`crate::lfa::Precision`]):
    /// `F64` is the reference path, `F32` runs symbol assembly *and* the
    /// solvers in f32 (twice the SIMD lanes), `F32Refined` adds an f64
    /// refinement pass per frequency. Factor-producing paths
    /// ([`Self::full_svd`], [`Self::topk_svd`]) always run
    /// in f64 regardless.
    precision: Precision,
    /// Row-axis phase table, flattened `[kh][n]`: `py[d·n + i] =
    /// e^{2πi·i·(d − anchor_row)/n}`.
    py: Vec<C64>,
    /// Column-axis phase table, flattened `[kw][m]`.
    px: Vec<C64>,
    /// f32 twin of `py`, narrowed from the f64 table (so the f32 phases
    /// are the correctly rounded images of the reference phases).
    py32: Vec<C32>,
    /// f32 twin of `px`.
    px32: Vec<C32>,
    /// Kernel weights narrowed to f32 for reduced-precision symbol
    /// assembly, same OIHW-taps-innermost order as `kernel.data`.
    w32: Vec<f32>,
    /// Reusable per-worker workspaces (checked out per execution range).
    /// Owned by this plan alone, or shared with other equal-shape plans of a
    /// [`super::ModelPlan`] group.
    pool: Arc<WorkspacePool>,
}

impl SpectralPlan {
    /// Plan the dense (stride-1) pipeline for `kernel` on an `n×m` grid.
    pub fn new(kernel: &ConvKernel, n: usize, m: usize, opts: LfaOptions) -> Self {
        Self::with_stride(kernel, n, m, 1, opts)
    }

    /// Plan the stride-`s` pipeline (`C = D_s ∘ A`) on an `n×m` fine grid.
    /// The coarse output grid is `(n/s)×(m/s)`; `s` must divide both axes.
    pub fn with_stride(
        kernel: &ConvKernel,
        n: usize,
        m: usize,
        s: usize,
        opts: LfaOptions,
    ) -> Self {
        // Prewarm one workspace: the serial path never allocates at execute
        // time, and threaded paths grow the pool once on first use. Grouped
        // kernels solve per-group blocks, so the pool is sized per group.
        let pool = Arc::new(WorkspacePool::for_block(
            kernel.group_c_out(),
            s * s * kernel.c_in,
            kernel.kh * kernel.kw,
        ));
        Self::with_shared_pool(kernel, n, m, s, opts, pool)
    }

    /// [`Self::with_stride`] drawing scratch from an existing shared pool
    /// instead of creating one. This is how [`super::ModelPlan`] batches
    /// layers with equal block shape into one workspace-sharing group; the
    /// pool must cover this plan's `c_out × s²·c_in` blocks and tap count.
    pub fn with_shared_pool(
        kernel: &ConvKernel,
        n: usize,
        m: usize,
        s: usize,
        opts: LfaOptions,
        pool: Arc<WorkspacePool>,
    ) -> Self {
        assert!(s > 0 && n % s == 0 && m % s == 0, "stride must divide the grid");
        assert!(n > 0 && m > 0, "grid must be nonempty");
        assert!(
            kernel.groups >= 1 && kernel.c_out % kernel.groups == 0,
            "c_out {} not divisible by groups {}",
            kernel.c_out,
            kernel.groups
        );
        assert!(kernel.dilation >= 1, "dilation must be >= 1");
        assert!(
            pool.covers(kernel.group_c_out(), s * s * kernel.c_in, kernel.kh * kernel.kw),
            "workspace pool does not cover the plan's block shape"
        );
        let (ar, ac) = (kernel.anchor.0 as isize, kernel.anchor.1 as isize);
        // Dilation is a pure phase change: tap (r,c) sits at displacement
        // d·(r−ar, c−ac), so the tables absorb the factor d here and every
        // downstream path (fused sweeps, f32 twins) is dilation-correct for
        // free.
        let dil = kernel.dilation as isize;
        let mut py = vec![C64::ZERO; kernel.kh * n];
        for d in 0..kernel.kh {
            let dy = dil * (d as isize - ar);
            for i in 0..n {
                py[d * n + i] = C64::cis(2.0 * PI * (i as f64) * (dy as f64) / (n as f64));
            }
        }
        let mut px = vec![C64::ZERO; kernel.kw * m];
        for d in 0..kernel.kw {
            let dx = dil * (d as isize - ac);
            for j in 0..m {
                px[d * m + j] = C64::cis(2.0 * PI * (j as f64) * (dx as f64) / (m as f64));
            }
        }
        let block_rows = kernel.group_c_out();
        let block_cols = s * s * kernel.c_in;
        let py32: Vec<C32> = py.iter().map(|z| z.to_c32()).collect();
        let px32: Vec<C32> = px.iter().map(|z| z.to_c32()).collect();
        let w32: Vec<f32> = kernel.data.iter().map(|&v| v as f32).collect();
        Self {
            kernel: kernel.clone(),
            n,
            m,
            stride: s,
            layout: opts.layout,
            solver: opts.solver,
            threads: opts.threads,
            nc: n / s,
            mc: m / s,
            block_rows,
            block_cols,
            rank: kernel.groups * block_rows.min(block_cols),
            fold: opts.folding == Fold::Auto,
            precision: opts.precision,
            py,
            px,
            py32,
            px32,
            w32,
            pool,
        }
    }

    /// Rows of the coarse dual grid (the shardable axis).
    pub fn coarse_rows(&self) -> usize {
        self.nc
    }

    /// Columns of the coarse dual grid.
    pub fn coarse_cols(&self) -> usize {
        self.mc
    }

    /// Number of frequencies (= blocks) of the full dual grid.
    pub fn freqs(&self) -> usize {
        self.nc * self.mc
    }

    /// Whether conjugate-pair frequency folding is enabled
    /// ([`crate::lfa::Fold`] in the plan's options): full-grid executions
    /// then solve only [`Self::solved_freqs`] blocks and mirror the rest.
    pub fn folded(&self) -> bool {
        self.fold
    }

    /// Coarse frequency rows a folded full-grid execution solves: the
    /// fundamental-domain rows `0..=nc/2`. Equals [`Self::coarse_rows`]
    /// when folding is off — the shardable axis of the folded sweep.
    pub fn solved_rows(&self) -> usize {
        if self.fold {
            self.nc / 2 + 1
        } else {
            self.nc
        }
    }

    /// Whether coarse row `ki` is its own mirror under `θ → −θ` (the DC
    /// row, and the Nyquist row for even `nc`).
    #[inline]
    fn row_self_paired(&self, ki: usize) -> bool {
        ki == 0 || 2 * ki == self.nc
    }

    /// Canonical columns a folded sweep solves in row `ki`: self-paired
    /// rows fold along the column axis too (`0..=mc/2`), every other
    /// fundamental-domain row is solved in full.
    #[inline]
    fn fold_row_cols(&self, ki: usize) -> usize {
        if self.row_self_paired(ki) {
            self.mc / 2 + 1
        } else {
            self.mc
        }
    }

    /// Block SVDs a full-grid execution performs: the fundamental-domain
    /// size when folding (every conjugate pair solved once, self-paired
    /// frequencies solved exactly once — the one counting rule lives in
    /// [`crate::lfa::spectrum::folded_freqs`]), [`Self::freqs`] otherwise.
    pub fn solved_freqs(&self) -> usize {
        if self.fold {
            crate::lfa::spectrum::folded_freqs(self.nc, self.mc)
        } else {
            self.freqs()
        }
    }

    /// Conjugate mirror of coarse frequency `(ki, kj)`.
    #[inline]
    fn mirror_coords(&self, ki: usize, kj: usize) -> (usize, usize) {
        ((self.nc - ki) % self.nc, (self.mc - kj) % self.mc)
    }

    /// Emit the in-row conjugate mirrors of a folded self-paired row into
    /// the sink (`σ(ki, kj) = σ(ki, mc − kj)` for every `kj ≥ cols`); a
    /// no-op for full rows and unfolded sweeps (`cols == mc`). Part of the
    /// unified sweep so the mirror index arithmetic exists exactly once.
    #[inline]
    fn emit_row_tail<S: SpectrumSink>(&self, ki: usize, cols: usize, sink: &mut S) {
        for kj in cols..self.mc {
            let src = ki * self.mc + (self.mc - kj);
            sink.mirror(src, ki * self.mc + kj);
        }
    }

    /// Cut the folded row range `0..solved_rows()` into contiguous strips
    /// of roughly equal **solved-block** count for `threads` workers
    /// (self-paired rows carry about half the work of a full row) — the
    /// partition both folded threaded sweeps hand out, defined exactly
    /// once.
    fn fold_strips(&self, threads: usize) -> Vec<(usize, usize)> {
        let srows = self.solved_rows();
        let target = self.solved_freqs().div_ceil(threads).max(1);
        let mut strips = Vec::with_capacity(threads);
        let mut lo = 0usize;
        while lo < srows {
            let mut hi = lo;
            let mut acc = 0usize;
            while hi < srows && acc < target {
                acc += self.fold_row_cols(hi);
                hi += 1;
            }
            strips.push((lo, hi));
            lo = hi;
        }
        strips
    }

    /// Whether `(ki, kj)` lies in the canonical fundamental domain (the
    /// set a folded execution solves directly).
    #[inline]
    fn freq_is_canonical(&self, ki: usize, kj: usize) -> bool {
        ki <= self.nc / 2 && (!self.row_self_paired(ki) || kj <= self.mc / 2)
    }

    /// The folding mode the plan was built with.
    pub fn folding(&self) -> Fold {
        if self.fold {
            Fold::Auto
        } else {
            Fold::Off
        }
    }

    /// The scalar width the plan's sweeps execute at.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// The options the plan was built with (threads as given, 0 = auto).
    pub fn options(&self) -> LfaOptions {
        LfaOptions {
            layout: self.layout,
            solver: self.solver,
            threads: self.threads,
            folding: self.folding(),
            precision: self.precision,
        }
    }

    /// Content signature of the spectrum `request` computes on this plan —
    /// the key [`crate::engine::SpectralCache`] addresses results by.
    pub fn result_signature(&self, request: SpectrumRequest) -> crate::engine::Signature {
        crate::engine::Signature::result(
            &self.kernel,
            self.n,
            self.m,
            self.stride,
            &self.options(),
            request,
        )
    }

    /// Content signature of the density `req` computes on this plan — the
    /// key [`crate::engine::SpectralCache`] addresses density results by.
    pub fn density_signature(&self, req: DensityRequest) -> crate::engine::Signature {
        self.result_signature(SpectrumRequest::Full).for_density(req)
    }

    /// Singular values per frequency: `min(c_out, stride²·c_in_total)`
    /// (equivalently `groups · min(c_out/g, stride²·c_in)` — the union of
    /// the per-group block spectra). Transposition does not change it.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Per-group rank `min(c_out/g, stride²·c_in)` — the singular values
    /// one diagonal block contributes per frequency.
    #[inline]
    fn group_rank(&self) -> usize {
        self.block_rows.min(self.block_cols)
    }

    /// Total output length of a `SpectrumRequest::Full` execution.
    pub fn values_len(&self) -> usize {
        self.freqs() * self.rank
    }

    /// Values per frequency a `TopK(k)` execution stores: `k` clamped to
    /// the per-frequency rank (and at least 1).
    pub fn topk_per_freq(&self, k: usize) -> usize {
        SpectrumRequest::TopK(k).values_per_freq(self.rank)
    }

    /// Total output length of a `SpectrumRequest::TopK(k)` execution.
    pub fn topk_values_len(&self, k: usize) -> usize {
        self.freqs() * self.topk_per_freq(k)
    }

    /// Output length of an execution of `request`
    /// ([`Self::values_len`] / [`Self::topk_values_len`]).
    pub fn request_values_len(&self, request: SpectrumRequest) -> usize {
        self.freqs() * request.values_per_freq(self.rank)
    }

    /// The solver the plan was built with.
    pub fn solver(&self) -> BlockSolver {
        self.solver
    }

    /// Per-frequency **solved** block shape `(c_out/groups, stride²·c_in)`
    /// — one group's diagonal block (the whole symbol when `groups == 1`).
    pub fn block_shape(&self) -> (usize, usize) {
        (self.block_rows, self.block_cols)
    }

    /// Shape of the whole per-frequency symbol of the operator the plan
    /// audits: `(c_out, stride²·c_in_total)` for a forward convolution
    /// (block-diagonal when grouped), swapped when the kernel is
    /// transposed (the adjoint symbol is the conjugate transpose). This —
    /// not [`Self::block_shape`] — is the shape [`Spectrum`] / factor
    /// metadata carries.
    pub fn sym_shape(&self) -> (usize, usize) {
        let rows = self.kernel.c_out;
        let cols = self.block_cols * self.kernel.groups;
        if self.kernel.transposed {
            (cols, rows)
        } else {
            (rows, cols)
        }
    }

    /// Channel groups of the planned kernel (1 = dense mixing).
    pub fn groups(&self) -> usize {
        self.kernel.groups
    }

    /// Tap dilation of the planned kernel (1 = dense lattice).
    pub fn dilation(&self) -> usize {
        self.kernel.dilation
    }

    /// Whether the plan audits the adjoint (transposed) operator.
    pub fn transposed(&self) -> bool {
        self.kernel.transposed
    }

    /// The stride the plan was built with (1 = dense).
    pub fn stride(&self) -> usize {
        self.stride
    }

    /// Rows of the fine input grid (`coarse_rows · stride`).
    pub fn fine_rows(&self) -> usize {
        self.n
    }

    /// Columns of the fine input grid (`coarse_cols · stride`).
    pub fn fine_cols(&self) -> usize {
        self.m
    }

    /// The kernel the plan owns (a clone of the one it was built from).
    pub fn kernel(&self) -> &ConvKernel {
        &self.kernel
    }

    /// Worker count the plan will use (0 in options means auto).
    pub fn effective_threads(&self) -> usize {
        // Tiny grids: thread spawn overhead dominates the whole pipeline.
        if self.freqs() < 64 {
            return 1;
        }
        super::resolve_threads(self.threads).min(self.solved_rows().max(1))
    }

    /// Check a workspace out of the plan's pool (or build a fresh one if all
    /// are in use). Return it with [`Self::restore`] so later executions and
    /// other workers — including other plans sharing the pool — can reuse
    /// the buffers.
    pub fn checkout(&self) -> Workspace {
        self.pool.checkout()
    }

    /// Return a checked-out workspace to the pool.
    pub fn restore(&self, ws: Workspace) {
        self.pool.restore(ws);
    }

    /// The workspace pool this plan draws from (shared across a
    /// [`super::ModelPlan`] group, private otherwise).
    pub fn workspace_pool(&self) -> &Arc<WorkspacePool> {
        &self.pool
    }

    /// Column visited at `step` of a serpentine (boustrophedon) row sweep:
    /// even rows (relative to the sweep's start row) run left to right, odd
    /// rows right to left, so consecutive visits are always dual-grid
    /// neighbors. The single definition of the locality-preserving order
    /// that both the top-k values sweep and the factors sweep follow — the
    /// warm-start guarantee lives here and nowhere else.
    #[inline]
    fn serpentine_col(&self, row_in_range: usize, step: usize) -> usize {
        if row_in_range % 2 == 1 {
            self.mc - 1 - step
        } else {
            step
        }
    }

    /// Fill `ws.block` with group `gi`'s diagonal block of the symbol at
    /// coarse frequency `(ki, kj)`: the `(c_out/g)×c_in` per-group symbol
    /// for stride 1, the horizontal concatenation
    /// `(1/s)·[A_{k_00} | … | A_{k_(s-1)(s-1)}]` for stride `s` (`gi = 0`
    /// is the whole symbol for dense kernels). Uses only the precomputed
    /// phase tables — no trig, no allocation; dilation is already folded
    /// into the tables. The tap contraction stores the per-tap phases as
    /// split re/im planes and runs both dot products in one fused
    /// [`SimdReal::dot_split`] pass.
    fn fill_block(&self, ki: usize, kj: usize, gi: usize, ws: &mut Workspace) {
        let (kh, kw) = (self.kernel.kh, self.kernel.kw);
        let cin = self.kernel.c_in;
        let s = self.stride;
        let ntaps = kh * kw;
        let inv_s = 1.0 / s as f64;
        for a in 0..s {
            for b in 0..s {
                // Fine frequency this sub-block aliases from.
                let fi = ki + a * self.nc;
                let fj = kj + b * self.mc;
                // Combine the two 1-D tables into split per-tap phases.
                for r in 0..kh {
                    let pyr = self.py[r * self.n + fi];
                    for c in 0..kw {
                        let ph = pyr * self.px[c * self.m + fj];
                        ws.tap_re[r * kw + c] = ph.re;
                        ws.tap_im[r * kw + c] = ph.im;
                    }
                }
                // Contract taps against the OIHW weight tensor; taps are the
                // innermost stride, so each (o, i) pair's weights are
                // contiguous. Group gi's output channels start at
                // gi·block_rows in the stored tensor.
                let col0 = (a * s + b) * cin;
                for o in 0..self.block_rows {
                    for i in 0..cin {
                        let p = (gi * self.block_rows + o) * cin + i;
                        let w = &self.kernel.data[p * ntaps..(p + 1) * ntaps];
                        let (re, im) =
                            f64::dot_split(w, &ws.tap_re[..ntaps], &ws.tap_im[..ntaps]);
                        let mut acc = C64::new(re, im);
                        if s > 1 {
                            acc = acc.scale(inv_s);
                        }
                        ws.block[o * self.block_cols + col0 + i] = acc;
                    }
                }
            }
        }
    }

    /// f32 twin of [`Self::fill_block`]: assembles group `gi`'s block into
    /// `ws.block32` from the narrowed phase tables and weights — the
    /// reduced-precision tiers' symbol stage, at twice the SIMD lanes.
    fn fill_block32(&self, ki: usize, kj: usize, gi: usize, ws: &mut Workspace) {
        let (kh, kw) = (self.kernel.kh, self.kernel.kw);
        let cin = self.kernel.c_in;
        let s = self.stride;
        let ntaps = kh * kw;
        let inv_s = 1.0f32 / s as f32;
        for a in 0..s {
            for b in 0..s {
                let fi = ki + a * self.nc;
                let fj = kj + b * self.mc;
                for r in 0..kh {
                    let pyr = self.py32[r * self.n + fi];
                    for c in 0..kw {
                        let ph = pyr * self.px32[c * self.m + fj];
                        ws.tap_re32[r * kw + c] = ph.re;
                        ws.tap_im32[r * kw + c] = ph.im;
                    }
                }
                let col0 = (a * s + b) * cin;
                for o in 0..self.block_rows {
                    for i in 0..cin {
                        let p = (gi * self.block_rows + o) * cin + i;
                        let w = &self.w32[p * ntaps..(p + 1) * ntaps];
                        let (re, im) =
                            f32::dot_split(w, &ws.tap_re32[..ntaps], &ws.tap_im32[..ntaps]);
                        let mut acc = C32::new(re, im);
                        if s > 1 {
                            acc = acc.scale(inv_s);
                        }
                        ws.block32[o * self.block_cols + col0 + i] = acc;
                    }
                }
            }
        }
    }

    /// Assemble and solve one group block of frequency `(ki, kj)` at the
    /// plan's precision: the block's singular values, descending, into
    /// `dst` (`group_rank` long, always f64 at the output boundary). The
    /// single dispatch point of the full-sweep precision tiers — and of the
    /// **escalation ladder**: a solve whose certificate reports
    /// non-convergence (the certified solvers already retried once from
    /// fresh rotations internally) is re-assembled in f64 and re-solved by
    /// the full one-sided Jacobi SVD, the crate's most robust path. The
    /// one rung covers every tier at once: GramEigen → Jacobi, f32 → f64,
    /// refined → reference. Only if that rung *also* fails to certify does
    /// the frequency count as degraded.
    #[inline]
    fn solve_group(
        &self,
        ki: usize,
        kj: usize,
        gi: usize,
        ws: &mut Workspace,
        dst: &mut [f64],
    ) -> FreqVerdict {
        let cert = match self.precision {
            Precision::F64 => {
                self.fill_block(ki, kj, gi, ws);
                ws.solve_block(self.solver, self.block_rows, self.block_cols, dst)
            }
            Precision::F32 => {
                self.fill_block32(ki, kj, gi, ws);
                ws.solve_block32(self.solver, self.block_rows, self.block_cols, dst)
            }
            Precision::F32Refined => {
                self.fill_block(ki, kj, gi, ws);
                ws.solve_block_refined(self.block_rows, self.block_cols, dst)
            }
        };
        if cert.converged {
            return FreqVerdict::from_cert(cert);
        }
        self.escalate_group(ki, kj, gi, ws, dst, cert.residual)
    }

    /// The escalation rung: re-assemble group `gi`'s block in f64 and
    /// re-solve with the full one-sided Jacobi SVD, overwriting `dst`.
    /// `prev_residual` is the failed attempt's residual — kept as the
    /// reported worst case if even this rung cannot certify.
    fn escalate_group(
        &self,
        ki: usize,
        kj: usize,
        gi: usize,
        ws: &mut Workspace,
        dst: &mut [f64],
        prev_residual: f64,
    ) -> FreqVerdict {
        self.fill_block(ki, kj, gi, ws);
        let esc = ws.solve_block(BlockSolver::Jacobi, self.block_rows, self.block_cols, dst);
        FreqVerdict {
            converged: esc.converged,
            retried: true,
            escalations: 1,
            residual: if esc.converged { esc.residual } else { esc.residual.max(prev_residual) },
        }
    }

    /// Assemble and solve frequency `(ki, kj)` at the plan's precision:
    /// full per-frequency singular values of the (block-diagonal)
    /// operator, descending, into `dst` (`rank` long). Dense kernels solve
    /// one block; grouped kernels solve `g` per-group blocks — `O(g²)`
    /// cheaper than one dense SVD of the embedded matrix — and merge the
    /// group spectra by an in-place sort (the singular values of a
    /// block-diagonal matrix are the union of its blocks').
    #[inline]
    fn solve_freq(&self, ki: usize, kj: usize, ws: &mut Workspace, dst: &mut [f64]) -> FreqVerdict {
        let g = self.kernel.groups;
        if g == 1 {
            return self.solve_group(ki, kj, 0, ws, dst);
        }
        let gr = self.group_rank();
        let mut verdict =
            FreqVerdict { converged: true, retried: false, escalations: 0, residual: 0.0 };
        for gi in 0..g {
            let (lo, hi) = (gi * gr, (gi + 1) * gr);
            verdict.absorb(self.solve_group(ki, kj, gi, ws, &mut dst[lo..hi]));
        }
        dst.sort_unstable_by(|a, b| b.total_cmp(a));
        verdict
    }

    /// Top-k companion of [`Self::solve_freq`]: assemble and solve
    /// frequency `(ki, kj)` for its `ke` largest values at the plan's
    /// precision. Returns the solver iteration steps spent and the
    /// frequency's convergence verdict after the escalation ladder: a
    /// Krylov solve whose Ritz residuals miss the tolerance within budget
    /// falls back to the full f64 Jacobi SVD of the block
    /// ([`Self::escalate_topk_group`]) and takes the top `ke` of that.
    ///
    /// Grouped kernels solve each diagonal block for its own
    /// `min(ke, group_rank)` extremes (cold-started per block — a warm
    /// basis from a *different* group's block is meaningless), gather the
    /// candidates in `ws.merge`, and copy the global top `ke` out: the
    /// top-k of a block-diagonal matrix is the top-k of the union of its
    /// blocks' top-k.
    #[inline]
    fn solve_freq_topk(
        &self,
        ki: usize,
        kj: usize,
        ke: usize,
        opts: TopKOptions,
        ws: &mut Workspace,
        dst: &mut [f64],
    ) -> (u64, FreqVerdict) {
        let g = self.kernel.groups;
        if g == 1 {
            let cert = self.solve_group_topk(ki, kj, 0, ke, opts, ws, dst);
            let iters = cert.effort as u64;
            if cert.converged {
                return (iters, FreqVerdict::from_cert(cert));
            }
            return (iters, self.escalate_topk_group(ki, kj, 0, ws, dst, cert.residual));
        }
        let kg = ke.min(self.group_rank());
        // The merge buffer is owned scratch: take it out so the per-group
        // solves can borrow `ws` mutably, put it back when done.
        let mut merge = std::mem::take(&mut ws.merge);
        if merge.len() < g * kg {
            merge.resize(g * kg, 0.0);
        }
        let mut iters = 0u64;
        let mut verdict =
            FreqVerdict { converged: true, retried: false, escalations: 0, residual: 0.0 };
        for gi in 0..g {
            self.topk_reset(ws);
            let sub = &mut merge[gi * kg..(gi + 1) * kg];
            let cert = self.solve_group_topk(ki, kj, gi, kg, opts, ws, sub);
            iters += cert.effort as u64;
            if cert.converged {
                verdict.absorb(FreqVerdict::from_cert(cert));
            } else {
                verdict.absorb(self.escalate_topk_group(ki, kj, gi, ws, sub, cert.residual));
            }
        }
        merge[..g * kg].sort_unstable_by(|a, b| b.total_cmp(a));
        dst.copy_from_slice(&merge[..ke]);
        ws.merge = merge;
        (iters, verdict)
    }

    /// One group block's top-`ke` Krylov solve at the plan's precision —
    /// the tier dispatch shared by the dense and grouped top-k paths.
    #[inline]
    fn solve_group_topk(
        &self,
        ki: usize,
        kj: usize,
        gi: usize,
        ke: usize,
        opts: TopKOptions,
        ws: &mut Workspace,
        dst: &mut [f64],
    ) -> SolveCert {
        match self.precision {
            Precision::F64 => {
                self.fill_block(ki, kj, gi, ws);
                ws.solve_block_topk(self.block_rows, self.block_cols, ke, opts, dst)
            }
            Precision::F32 => {
                self.fill_block32(ki, kj, gi, ws);
                ws.solve_block_topk32(self.block_rows, self.block_cols, ke, opts, dst)
            }
            Precision::F32Refined => {
                self.fill_block(ki, kj, gi, ws);
                ws.solve_block_topk_refined(self.block_rows, self.block_cols, ke, opts, dst)
            }
        }
    }

    /// Top-k escalation rung: solve group `gi`'s **whole** block spectrum
    /// by the full f64 Jacobi SVD and keep the top `dst.len()` values —
    /// trading the Krylov path's `O(c²k)` for a guaranteed-robust `O(c³)`
    /// on the (rare) frequency that refused to certify. The full-spectrum
    /// scratch borrows `ws.merge`; inside the grouped merge loop that
    /// buffer is already checked out, so this path may allocate a
    /// transient `group_rank`-length vector — acceptable on an
    /// escalation-only path.
    fn escalate_topk_group(
        &self,
        ki: usize,
        kj: usize,
        gi: usize,
        ws: &mut Workspace,
        dst: &mut [f64],
        prev_residual: f64,
    ) -> FreqVerdict {
        let gr = self.group_rank();
        let mut full = std::mem::take(&mut ws.merge);
        if full.len() < gr {
            full.resize(gr, 0.0);
        }
        let verdict = self.escalate_group(ki, kj, gi, ws, &mut full[..gr], prev_residual);
        dst.copy_from_slice(&full[..dst.len()]);
        ws.merge = full;
        verdict
    }

    /// Cold-start the top-k scratch the plan's precision actually sweeps
    /// with (`topk` for f64, `topk32` for both reduced tiers).
    #[inline]
    fn topk_reset(&self, ws: &mut Workspace) {
        match self.precision {
            Precision::F64 => ws.topk.reset(),
            Precision::F32 | Precision::F32Refined => ws.topk32.reset(),
        }
    }

    /// Conjugate the carried warm basis at a fold seam — on whichever
    /// scratch the plan's precision sweeps with.
    #[inline]
    fn topk_conjugate(&self, ws: &mut Workspace) {
        match self.precision {
            Precision::F64 => ws.topk.conjugate_basis(),
            Precision::F32 | Precision::F32Refined => ws.topk32.conjugate_basis(),
        }
    }

    /// The engine's **single frequency-iteration driver**: run `request`
    /// over rows `[row_lo, row_hi)` of the solved domain
    /// (fundamental-domain rows when the plan folds, all coarse rows
    /// otherwise), emitting every per-frequency result into `sink`. Owns
    /// the visit order — row-major for `Full`, serpentine /
    /// folded-serpentine for `TopK` so warm starts stay dual-grid-local
    /// (see [`Self::serpentine_col`] / [`Self::walk_fold_rows`]) — the
    /// fold bookkeeping (self-paired row tails are emitted as in-strip
    /// [`SpectrumSink::mirror`]s; rows below the fold line are assembly's
    /// job), the precision tiers and the escalation ladder (via
    /// [`Self::solve_freq`] / [`Self::solve_freq_topk`]), and one health
    /// verdict per solved frequency. Zero heap allocation per frequency:
    /// the sink hands back preallocated slots.
    ///
    /// Returns total solver iteration steps (0 for `Full` — the fused
    /// Jacobi path is direct) and the range's aggregated
    /// [`SpectrumHealth`].
    fn sweep<S: SpectrumSink>(
        &self,
        request: SpectrumRequest,
        row_lo: usize,
        row_hi: usize,
        warm_sweep: bool,
        ws: &mut Workspace,
        sink: &mut S,
    ) -> (u64, SpectrumHealth) {
        debug_assert!(row_lo <= row_hi && row_hi <= self.solved_rows());
        let mut health = SpectrumHealth::default();
        match request {
            SpectrumRequest::Full => {
                for ki in row_lo..row_hi {
                    let cols = if self.fold { self.fold_row_cols(ki) } else { self.mc };
                    for kj in 0..cols {
                        let f = ki * self.mc + kj;
                        self.solve_freq(ki, kj, ws, sink.slot(f)).record(&mut health);
                        sink.commit(f, ki, kj);
                    }
                    self.emit_row_tail(ki, cols, sink);
                }
                (0, health)
            }
            SpectrumRequest::TopK(k) => {
                let ke = self.topk_per_freq(k);
                let opts = TopKOptions::default();
                // Never inherit a basis from whatever this pooled workspace
                // did last (another strip, another layer): cold-start the
                // sweep.
                self.topk_reset(ws);
                let mut iters = 0u64;
                if self.fold {
                    self.walk_fold_rows(row_lo, row_hi, |ki, kj, crossed_seam| {
                        if crossed_seam {
                            self.topk_conjugate(ws);
                        }
                        if !warm_sweep {
                            self.topk_reset(ws);
                        }
                        let f = ki * self.mc + kj;
                        let (it, verdict) =
                            self.solve_freq_topk(ki, kj, ke, opts, ws, sink.slot(f));
                        sink.commit(f, ki, kj);
                        iters += it;
                        verdict.record(&mut health);
                    });
                    for ki in row_lo..row_hi {
                        self.emit_row_tail(ki, self.fold_row_cols(ki), sink);
                    }
                } else {
                    for ki in row_lo..row_hi {
                        for step in 0..self.mc {
                            let kj = self.serpentine_col(ki - row_lo, step);
                            if !warm_sweep {
                                self.topk_reset(ws);
                            }
                            let f = ki * self.mc + kj;
                            let (it, verdict) =
                                self.solve_freq_topk(ki, kj, ke, opts, ws, sink.slot(f));
                            sink.commit(f, ki, kj);
                            iters += it;
                            verdict.record(&mut health);
                        }
                    }
                }
                (iters, health)
            }
        }
    }

    /// Execute `request` for rows `[row_lo, row_hi)` of the **solved
    /// domain** (fundamental-domain rows `0..solved_rows()` when the plan
    /// folds — each self-paired row's mirrored columns are filled in-row,
    /// so every tile is self-contained; all coarse rows otherwise) into
    /// `out`: `(row_hi−row_lo)·mc·values_per_freq` values,
    /// frequency-major, descending per frequency. Rows below the fold line
    /// are nobody's tile — assembly fills them with
    /// [`crate::lfa::spectrum::mirror_fill`]. Zero heap allocation per
    /// frequency; returns solver iteration steps (0 for `Full`) and the
    /// range's [`SpectrumHealth`]. The strip primitive behind
    /// [`Self::execute_request_into`], `ModelPlan`'s batched sweeps and
    /// the coordinator's tile workers.
    pub(crate) fn execute_request_rows(
        &self,
        request: SpectrumRequest,
        row_lo: usize,
        row_hi: usize,
        warm_sweep: bool,
        ws: &mut Workspace,
        out: &mut [f64],
    ) -> (u64, SpectrumHealth) {
        debug_assert_eq!(
            out.len(),
            (row_hi - row_lo) * self.mc * request.values_per_freq(self.rank)
        );
        match request {
            SpectrumRequest::Full => {
                let mut sink = FullAssembly::strip(self, row_lo, out);
                self.sweep(request, row_lo, row_hi, warm_sweep, ws, &mut sink)
            }
            SpectrumRequest::TopK(k) => {
                let mut sink = TopKAssembly::strip(self, k, row_lo, out);
                self.sweep(request, row_lo, row_hi, warm_sweep, ws, &mut sink)
            }
        }
    }

    /// [`Self::execute_request_rows`] with pool-managed workspace checkout
    /// (warm-started within the range) — the tile entry point of the
    /// coordinator's workers against a shared plan.
    pub(crate) fn execute_request_rows_pooled(
        &self,
        request: SpectrumRequest,
        row_lo: usize,
        row_hi: usize,
        out: &mut [f64],
    ) -> (u64, SpectrumHealth) {
        let mut ws = self.checkout();
        let result = self.execute_request_rows(request, row_lo, row_hi, true, &mut ws, out);
        self.restore(ws);
        result
    }

    /// Direction of the folded serpentine sweep in row `ki`: `true` means
    /// the canonical columns are visited high→low. Chosen so consecutive
    /// solves stay dual-grid neighbors **in the torus metric**: a
    /// self-paired row opening a strip runs `mc/2 → 0` (the next row then
    /// enters adjacently at column 0), full rows run away from the
    /// previous row's end column (entering straight down at 0 or `mc−1`),
    /// and the closing self-paired row runs `0 → mc/2` — entered either
    /// straight down (previous end 0) or across the wrap seam from column
    /// `mc−1` to column 0 (a diagonal torus step).
    #[inline]
    fn fold_row_reverse(&self, ki: usize, first_in_strip: bool, prev_end: usize) -> bool {
        if self.row_self_paired(ki) {
            first_in_strip
        } else {
            prev_end != 0
        }
    }

    /// Walk the folded serpentine order over rows `[fr_lo, fr_hi)` of the
    /// fundamental domain, invoking `visit(ki, kj, crossed_seam)` at every
    /// canonical frequency. `crossed_seam` is true exactly on the first
    /// visit after the walk wraps across the fold seam into the closing
    /// self-paired row — the spot where a carried warm basis should be
    /// conjugated ([`crate::linalg::power::TopKScratch::conjugate_basis`]).
    /// The **single definition** of the folded visit order; the top-k
    /// values sweep and the factors sweep both follow it, so the seam and
    /// direction bookkeeping cannot drift between them.
    fn walk_fold_rows<F: FnMut(usize, usize, bool)>(
        &self,
        fr_lo: usize,
        fr_hi: usize,
        mut visit: F,
    ) {
        let mut prev_end = 0usize;
        for ki in fr_lo..fr_hi {
            let cols = self.fold_row_cols(ki);
            let first = ki == fr_lo;
            let reverse = self.fold_row_reverse(ki, first, prev_end);
            let seam = !first && self.row_self_paired(ki) && prev_end != 0;
            for step in 0..cols {
                let kj = if reverse { cols - 1 - step } else { step };
                visit(ki, kj, seam && step == 0);
            }
            prev_end = if reverse { 0 } else { cols - 1 };
        }
    }

    /// Execute `request` over the full dual grid into a caller-provided
    /// buffer (`request_values_len(request)` long) — **the** whole-grid
    /// request-driven driver every other entry point wraps. `opts` picks
    /// the worker count and warm-start policy ([`SweepOptions`]). Workers
    /// own contiguous strips of solved rows (folded plans partition the
    /// fundamental domain by solved-block count) and sweep them with the
    /// unified driver, so warm starts stay strip-local and never cross
    /// workers — results are deterministic for a fixed partition. When the
    /// plan folds ([`crate::lfa::Fold::Auto`], the default), only the
    /// fundamental domain of `θ → −θ` is solved and the conjugate half is
    /// filled by mirroring ([`crate::lfa::spectrum::mirror_fill`]) —
    /// roughly halving the SVD work. Allocation-free per frequency once
    /// warmed up. Returns the solver iteration steps spent (0 for `Full`
    /// — the fused Jacobi path is direct) and the sweep's aggregated
    /// [`SpectrumHealth`].
    pub fn execute_request_into(
        &self,
        request: SpectrumRequest,
        opts: SweepOptions,
        out: &mut [f64],
    ) -> (u64, SpectrumHealth) {
        assert_eq!(out.len(), self.request_values_len(request), "output buffer length mismatch");
        let warm = !opts.cold_start;
        let srows = self.solved_rows();
        let threads = match opts.threads {
            None => self.effective_threads(),
            Some(t) => super::resolve_threads(t),
        }
        .min(srows.max(1));
        let per = request.values_per_freq(self.rank);
        let row_vals = self.mc * per;
        let result = {
            let solved = &mut out[..srows * row_vals];
            if threads <= 1 || srows <= 1 {
                let mut ws = self.checkout();
                let result = self.execute_request_rows(request, 0, srows, warm, &mut ws, solved);
                self.restore(ws);
                result
            } else {
                let strips = if self.fold {
                    self.fold_strips(threads)
                } else {
                    let rows_per = self.nc.div_ceil(threads);
                    let mut strips = Vec::with_capacity(threads);
                    let mut lo = 0usize;
                    while lo < self.nc {
                        let hi = (lo + rows_per).min(self.nc);
                        strips.push((lo, hi));
                        lo = hi;
                    }
                    strips
                };
                let total = AtomicU64::new(0);
                let total_ref = &total;
                let agg = Mutex::new(SpectrumHealth::default());
                let agg_ref = &agg;
                std::thread::scope(|scope| {
                    let mut rest: &mut [f64] = solved;
                    for (lo, hi) in strips {
                        let (head, tail) =
                            std::mem::take(&mut rest).split_at_mut((hi - lo) * row_vals);
                        rest = tail;
                        scope.spawn(move || {
                            let mut ws = self.checkout();
                            let (iters, health) =
                                self.execute_request_rows(request, lo, hi, warm, &mut ws, head);
                            self.restore(ws);
                            total_ref.fetch_add(iters, Ordering::Relaxed);
                            agg_ref.lock().unwrap().merge(&health);
                        });
                    }
                });
                (total.into_inner(), agg.into_inner().unwrap())
            }
        };
        if self.fold {
            mirror_fill(self.nc, self.mc, per, out);
        }
        result
    }

    /// Run `request` through the unified sweep into a caller-supplied
    /// [`SpectrumSink`] — the pluggable seam new per-frequency consumers
    /// build on instead of forking a driver (the density analytics path is
    /// one: [`Self::density`]; see `docs/ARCHITECTURE.md`'s streaming
    /// pipeline section). Serial, whole solved domain, warm-started. Every
    /// canonical frequency is delivered as a `slot`/`commit` pair; when
    /// the plan folds, every non-canonical frequency is then delivered
    /// exactly once as a `mirror` of its committed conjugate partner
    /// (self-paired row tails during the sweep, below-fold rows
    /// afterwards). Returns solver iteration steps (0 for `Full`) and the
    /// aggregated [`SpectrumHealth`].
    pub fn sweep_with<S: SpectrumSink>(
        &self,
        request: SpectrumRequest,
        sink: &mut S,
    ) -> (u64, SpectrumHealth) {
        let mut ws = self.checkout();
        let result = self.sweep(request, 0, self.solved_rows(), true, &mut ws, sink);
        self.restore(ws);
        if self.fold {
            for ki in self.solved_rows()..self.nc {
                for kj in 0..self.mc {
                    let (mi, mj) = self.mirror_coords(ki, kj);
                    sink.mirror(mi * self.mc + mj, ki * self.mc + kj);
                }
            }
        }
        result
    }

    /// Streaming singular-value **density** of the operator: a histogram
    /// of the `n·m·rank` singular values over `[0, σ_max]` with exact
    /// extremes and optional coarse sub-lattice sampling of the dual grid
    /// — the bulk-shape analytics the asymptotic-distribution results (Yi
    /// 2020) justify, at `O((nc/s)·(mc/s))` full SVDs for sample step `s`
    /// instead of the full `O(nc·mc)`.
    ///
    /// Two passes: a warm top-1 Krylov sweep of the whole grid pins
    /// `σ_max` exactly (top-k-grade — the same accuracy contract as
    /// [`Self::execute_topk`]) and seeds the iteration/health ledger; the
    /// sampled sub-lattice of the solved domain is then solved in full and
    /// streamed into a [`DensitySink`], each canonical frequency weighted
    /// by its conjugate-mirror multiplicity so folding never biases the
    /// histogram. With `sample == 1` the histogram is a census (and is
    /// driven through the same unified sweep as every assembly sink); with
    /// `sample > 1` it is an estimate whose resolution-independent CDF
    /// error bar is reported as [`SpectralDensity::cdf_epsilon`]. σ_min is
    /// only known over the sampled set
    /// ([`SpectralDensity::sigma_min_sampled`]) — the Krylov extremes pass
    /// cannot see the small end.
    pub fn density(&self, req: DensityRequest) -> SpectralDensity {
        self.density_with(req, SweepOptions::default())
    }

    /// [`Self::density`] with explicit sweep knobs (worker count /
    /// warm-start policy for the extremes pass).
    pub fn density_with(&self, req: DensityRequest, opts: SweepOptions) -> SpectralDensity {
        let bins = req.bins.max(1) as usize;
        let sample = req.sample.max(1) as usize;
        // Pass 1: exact extremes — a warm top-1 sweep over the whole grid.
        let mut top = vec![0.0f64; self.request_values_len(SpectrumRequest::TopK(1))];
        let (iterations, mut health) =
            self.execute_request_into(SpectrumRequest::TopK(1), opts, &mut top);
        let sigma_max = top.iter().fold(0.0f64, |a, &b| a.max(b));
        drop(top);
        // Pass 2: stream the sampled sub-lattice of the solved domain
        // through a DensitySink (full per-frequency spectra).
        let rows: Vec<usize> = (0..self.solved_rows()).step_by(sample).collect();
        let threads = match opts.threads {
            None => self.effective_threads(),
            Some(t) => super::resolve_threads(t),
        }
        .min(rows.len().max(1));
        let mut sink = DensitySink::new(self, bins, sigma_max);
        let bulk_health = if threads <= 1 {
            let mut ws = self.checkout();
            let h = self.density_rows(&rows, sample, &mut ws, &mut sink);
            self.restore(ws);
            h
        } else {
            let chunk = rows.len().div_ceil(threads);
            let agg = Mutex::new((SpectrumHealth::default(), Vec::<DensitySink>::new()));
            let agg_ref = &agg;
            std::thread::scope(|scope| {
                for part in rows.chunks(chunk) {
                    scope.spawn(move || {
                        let mut ws = self.checkout();
                        let mut local = DensitySink::new(self, bins, sigma_max);
                        let h = self.density_rows(part, sample, &mut ws, &mut local);
                        self.restore(ws);
                        let mut guard = agg_ref.lock().unwrap();
                        guard.0.merge(&h);
                        guard.1.push(local);
                    });
                }
            });
            let (h, parts) = agg.into_inner().unwrap();
            for part in &parts {
                sink.merge(part);
            }
            h
        };
        health.merge(&bulk_health);
        sink.into_density(self, req, sigma_max, iterations, health)
    }

    /// Solve the full spectra of the sampled canonical frequencies of
    /// `rows` (columns stepped by `sample`) into `sink`. A `sample` of 1
    /// covers a contiguous row range and routes through the unified
    /// [`Self::sweep`] — the same driver the assembly sinks ride — so the
    /// census path exercises the pluggable seam end to end.
    fn density_rows(
        &self,
        rows: &[usize],
        sample: usize,
        ws: &mut Workspace,
        sink: &mut DensitySink,
    ) -> SpectrumHealth {
        if rows.is_empty() {
            return SpectrumHealth::default();
        }
        if sample == 1 {
            let (lo, hi) = (rows[0], rows[rows.len() - 1] + 1);
            let (_, health) = self.sweep(SpectrumRequest::Full, lo, hi, true, ws, sink);
            return health;
        }
        let mut health = SpectrumHealth::default();
        for &ki in rows {
            let cols = if self.fold { self.fold_row_cols(ki) } else { self.mc };
            let mut kj = 0usize;
            while kj < cols {
                let f = ki * self.mc + kj;
                self.solve_freq(ki, kj, ws, sink.slot(f)).record(&mut health);
                sink.commit(f, ki, kj);
                kj += sample;
            }
        }
        health
    }

    /// Top-`k` singular values per frequency, warm-started along the
    /// plan's serpentine sweep — the partial-spectrum companion of
    /// [`Self::execute`], at `O(n·m·c²k)` per converged iteration instead
    /// of the full `O(n·m·c³)`.
    ///
    /// ```
    /// use conv_svd_lfa::conv::ConvKernel;
    /// use conv_svd_lfa::engine::SpectralPlan;
    /// use conv_svd_lfa::lfa::LfaOptions;
    /// use conv_svd_lfa::numeric::Pcg64;
    ///
    /// let mut rng = Pcg64::seeded(11);
    /// let kernel = ConvKernel::random_he(6, 6, 3, 3, &mut rng);
    /// let plan = SpectralPlan::new(&kernel, 8, 8, LfaOptions::default());
    /// // Only the two extreme values per frequency (σ_max lives here) …
    /// let top = plan.execute_topk(2);
    /// assert_eq!(top.spectrum.rank_per_freq(), 2);
    /// // … and they match the full pipeline's extremes.
    /// let full = plan.execute();
    /// assert!((top.spectrum.sigma_max() - full.sigma_max()).abs() < 1e-8);
    /// assert!(top.iterations > 0);
    /// ```
    pub fn execute_topk(&self, k: usize) -> TopKResult {
        let mut values = vec![0.0f64; self.topk_values_len(k)];
        let (iterations, health) = self.execute_request_into(
            SpectrumRequest::TopK(k),
            SweepOptions::default(),
            &mut values,
        );
        TopKResult { spectrum: self.topk_spectrum(k, values, health), iterations }
    }

    /// Package a flat top-k buffer as a partial [`Spectrum`].
    fn topk_spectrum(&self, k: usize, values: Vec<f64>, health: SpectrumHealth) -> Spectrum {
        self.spectrum_from_values_health(SpectrumRequest::TopK(k), values, health)
    }

    /// Package a flat values buffer produced by executing `request` on
    /// this plan into a [`Spectrum`] carrying the plan's shape metadata
    /// (coarse grid, block shape, values per frequency). Every path that
    /// materializes a spectrum from raw values — direct execution,
    /// `ModelPlan` assembly, the scheduler's job finish, the result
    /// cache — routes through here, so the shape fields cannot drift
    /// between them.
    pub fn spectrum_from_values(&self, request: SpectrumRequest, values: Vec<f64>) -> Spectrum {
        // No health evidence travels with a bare values buffer; report the
        // clean certificate. This is the cache-hit path — degraded spectra
        // are never admitted to the caches, so a reconstructed hit is
        // converged by construction.
        let health = SpectrumHealth::clean(self.solved_freqs() as u64);
        self.spectrum_from_values_health(request, values, health)
    }

    /// [`Self::spectrum_from_values`] carrying the convergence evidence a
    /// live execution produced — the packaging the scheduler's job-finish
    /// path uses so tile-level health survives into the job's [`Spectrum`].
    pub fn spectrum_from_values_health(
        &self,
        request: SpectrumRequest,
        values: Vec<f64>,
        health: SpectrumHealth,
    ) -> Spectrum {
        assert_eq!(
            values.len(),
            self.request_values_len(request),
            "values buffer length mismatch"
        );
        let (rows, cols) = self.sym_shape();
        Spectrum {
            n: self.nc,
            m: self.mc,
            c_out: rows,
            c_in: cols,
            per_freq: request.values_per_freq(self.rank),
            values,
            health,
        }
    }

    /// Solve the block currently in `ws` for its top-`ke` triplet and
    /// store it at frequency `f` of the factor assembly: values into
    /// `fa.values`, right vectors into `fa.v[f]`, left vectors
    /// `u_j = (A v_j)/σ_j` into `fa.u[f]`. Returns the solver certificate
    /// — the per-frequency body shared by the folded and unfolded factor
    /// sweeps (dense kernels; grouped kernels go through the
    /// candidate-merging path of [`Self::topk_triplet_at`]).
    fn store_topk_triplet(
        &self,
        ke: usize,
        opts: TopKOptions,
        ws: &mut Workspace,
        f: usize,
        fa: &mut FactorAssembly,
    ) -> SolveCert {
        let FactorAssembly { values, u, v, .. } = fa;
        let dst = &mut values[f * ke..(f + 1) * ke];
        let cert = ws.solve_block_topk(self.block_rows, self.block_cols, ke, opts, dst);
        for j in 0..ke {
            let vj = ws.topk.right_vector(j);
            for c in 0..self.block_cols {
                v[f][(c, j)] = vj[c];
            }
            // A v_j = σ_j u_j ⇒ u_j = (A v_j)/σ_j (zero if σ_j = 0).
            let inv = if dst[j] > 0.0 { 1.0 / dst[j] } else { 0.0 };
            let wj = ws.topk.left_scaled(j);
            for r in 0..self.block_rows {
                u[f][(r, j)] = wj[r].scale(inv);
            }
        }
        cert
    }

    /// Assemble, solve and store the top-`ke` forward triplet of frequency
    /// `(ki, kj)` at index `f` of the factor assembly; returns
    /// `(iterations, block energy)`. The per-frequency body of
    /// [`Self::topk_svd`], shared by the folded and unfolded sweeps. Dense
    /// kernels solve the single block in place; grouped kernels solve each
    /// diagonal block for its own `min(ke, group_rank)` candidate triplets
    /// (cold per block), merge by value in `fs`, and embed the winners'
    /// vectors at their group's row/column offsets of the block-diagonal
    /// factor matrices.
    #[allow(clippy::too_many_arguments)]
    fn topk_triplet_at(
        &self,
        ki: usize,
        kj: usize,
        ke: usize,
        opts: TopKOptions,
        ws: &mut Workspace,
        fs: &mut Option<FactorScratch>,
        f: usize,
        fa: &mut FactorAssembly,
        health: &mut SpectrumHealth,
    ) -> (u64, f64) {
        let g = self.kernel.groups;
        if g == 1 {
            self.fill_block(ki, kj, 0, ws);
            let energy = ws.block.iter().map(|z| z.norm_sqr()).sum::<f64>();
            let cert = self.store_topk_triplet(ke, opts, ws, f, fa);
            FreqVerdict::from_cert(cert).record(health);
            return (cert.effort as u64, energy);
        }
        let FactorAssembly { values, u, v, .. } = fa;
        let FactorScratch { vals, order, u: cand_u, v: cand_v } =
            fs.as_mut().expect("grouped factor sweep requires candidate scratch");
        let kg = ke.min(self.group_rank());
        let (cin, cin_total) = (self.kernel.c_in, self.kernel.c_in_total());
        let mut iters = 0u64;
        let mut energy = 0.0f64;
        let mut verdict =
            FreqVerdict { converged: true, retried: false, escalations: 0, residual: 0.0 };
        for gi in 0..g {
            // A warm basis from another group's block is meaningless.
            ws.topk.reset();
            self.fill_block(ki, kj, gi, ws);
            energy += ws.block.iter().map(|z| z.norm_sqr()).sum::<f64>();
            let sub = &mut vals[gi * kg..(gi + 1) * kg];
            let cert = ws.solve_block_topk(self.block_rows, self.block_cols, kg, opts, sub);
            iters += cert.effort as u64;
            verdict.absorb(FreqVerdict::from_cert(cert));
            for j in 0..kg {
                let c = gi * kg + j;
                let vj = ws.topk.right_vector(j);
                for row in 0..self.block_cols {
                    cand_v[(row, c)] = vj[row];
                }
                let inv = if sub[j] > 0.0 { 1.0 / sub[j] } else { 0.0 };
                let wj = ws.topk.left_scaled(j);
                for r in 0..self.block_rows {
                    cand_u[(r, c)] = wj[r].scale(inv);
                }
            }
        }
        // Global top-ke across the g·kg candidates (the top-k of a
        // block-diagonal matrix is the top-k of the union of its blocks').
        order.clear();
        order.extend(0..g * kg);
        order.sort_unstable_by(|&a, &b| vals[b].total_cmp(&vals[a]));
        for (j2, &c) in order.iter().take(ke).enumerate() {
            let gi = c / kg;
            values[f * ke + j2] = vals[c];
            for r in 0..self.block_rows {
                u[f][(gi * self.block_rows + r, j2)] = cand_u[(r, c)];
            }
            for row in 0..self.block_cols {
                let (ab, i) = (row / cin, row % cin);
                v[f][(ab * cin_total + gi * cin + i, j2)] = cand_v[(row, c)];
            }
        }
        verdict.record(health);
        (iters, energy)
    }

    /// Right factor of the conjugate mirror of frequency `(ki, kj)`:
    /// `V(−κ) = Pᵀ·conj(V(κ))` — rows permuted per aliasing group by the
    /// stride negation permutation
    /// ([`crate::lfa::stride::alias_mirror_index`]), entries conjugated.
    /// For stride 1 this reduces to the plain conjugate. The factor rows
    /// are `(a,b)`-alias-major with `c_in_total` channels per alias, so
    /// the permutation is oblivious to channel grouping — it moves whole
    /// alias row groups.
    pub(crate) fn mirror_right_factor(&self, vsrc: &CMat, ki: usize, kj: usize) -> CMat {
        let s = self.stride;
        if s == 1 {
            return conj_factor(vsrc);
        }
        let cin = self.kernel.c_in_total();
        let mut out = CMat::zeros(vsrc.rows, vsrc.cols);
        for a in 0..s {
            for b in 0..s {
                let sa = alias_mirror_index(s, ki == 0, a);
                let sb = alias_mirror_index(s, kj == 0, b);
                let dst0 = (a * s + b) * cin;
                let src0 = (sa * s + sb) * cin;
                for i in 0..cin {
                    for j in 0..vsrc.cols {
                        out[(dst0 + i, j)] = vsrc[(src0 + i, j)].conj();
                    }
                }
            }
        }
        out
    }

    /// Top-`k` singular **triplets** per frequency: values plus left/right
    /// singular vectors, the inputs low-rank compression needs
    /// ([`crate::spectral::lowrank::compress_from_topk`]). Serial
    /// warm-started sweep over the folded fundamental domain (mirrored
    /// frequencies get copied values, conjugated `U` and permuted-conjugate
    /// `V` — exact by the symbol symmetry) or, with folding off, over the
    /// whole grid. The factor matrices are fresh allocations by necessity —
    /// they are the output. Always executes in f64 regardless of the
    /// plan's [`Precision`]: the vectors are consumed downstream
    /// (compression, reconstruction) where reduced precision would
    /// compound.
    /// Grouped kernels solve each diagonal block for its own candidates
    /// and merge (see [`Self::topk_triplet_at`]); transposed kernels solve
    /// the forward blocks and swap the `U`/`V` roles at packaging (the
    /// adjoint symbol is the conjugate transpose, so `Aᴴ = VΣUᴴ`).
    ///
    /// Convergence certificates are aggregated into the returned
    /// `sigma.health`; a frequency whose Krylov solve cannot certify is
    /// flagged degraded — the values-path Jacobi escalation rung produces
    /// no singular vectors, so the factor sweep flags rather than
    /// escalates.
    pub fn topk_svd(&self, k: usize) -> TopKSvd {
        let ke = self.topk_per_freq(k);
        let opts = TopKOptions::default();
        let g = self.kernel.groups;
        // Forward-operator factor shapes; swapped at packaging when
        // transposed.
        let (fwd_rows, fwd_cols) = (self.kernel.c_out, self.block_cols * g);
        let mut fa = FactorAssembly::new(self, ke, fwd_rows, fwd_cols);
        let kg = ke.min(self.group_rank());
        let mut fs = if g > 1 {
            Some(FactorScratch {
                vals: vec![0.0f64; g * kg],
                order: Vec::with_capacity(g * kg),
                u: CMat::zeros(self.block_rows, g * kg),
                v: CMat::zeros(self.block_cols, g * kg),
            })
        } else {
            None
        };
        let mut ws = self.checkout();
        ws.topk.reset();
        let mut iters = 0u64;
        let mut total_energy = 0.0f64;
        let mut health = SpectrumHealth::default();
        if self.fold {
            self.walk_fold_rows(0, self.solved_rows(), |ki, kj, crossed_seam| {
                if crossed_seam {
                    ws.topk.conjugate_basis();
                }
                let f = ki * self.mc + kj;
                let (it, energy) = self.topk_triplet_at(
                    ki, kj, ke, opts, &mut ws, &mut fs, f, &mut fa, &mut health,
                );
                iters += it;
                total_energy += energy;
                let (mi, mj) = self.mirror_coords(ki, kj);
                let fm = mi * self.mc + mj;
                if fm != f {
                    // The mirror carries the same energy and values,
                    // conjugated factors.
                    total_energy += energy;
                    fa.mirror_triplet(self, f, fm, ki, kj);
                }
            });
        } else {
            for ki in 0..self.nc {
                for step in 0..self.mc {
                    let kj = self.serpentine_col(ki, step);
                    let f = ki * self.mc + kj;
                    let (it, energy) = self.topk_triplet_at(
                        ki, kj, ke, opts, &mut ws, &mut fs, f, &mut fa, &mut health,
                    );
                    iters += it;
                    total_energy += energy;
                }
            }
        }
        self.restore(ws);
        let (sym_rows, sym_cols) = self.sym_shape();
        let FactorAssembly { values, u, v, .. } = fa;
        let sigma = self.topk_spectrum(k, values, health);
        let (u, v) = if self.kernel.transposed { (v, u) } else { (u, v) };
        TopKSvd {
            n: self.nc,
            m: self.mc,
            c_out: sym_rows,
            c_in: sym_cols,
            k: ke,
            u,
            sigma,
            v,
            iterations: iters,
            total_energy,
        }
    }

    /// Execute the full dual grid and package the result as a [`Spectrum`]
    /// (carrying the sweep's aggregated [`SpectrumHealth`]).
    pub fn execute(&self) -> Spectrum {
        let mut values = vec![0.0f64; self.values_len()];
        let (_, health) = self.execute_request_into(
            SpectrumRequest::Full,
            SweepOptions::default(),
            &mut values,
        );
        self.spectrum_from_values_health(SpectrumRequest::Full, values, health)
    }

    /// Full SVD with per-frequency factors `U_k, Σ_k, V_k` (the factor
    /// matrices are fresh allocations by necessity — they are the output).
    /// When the plan folds, only the fundamental domain is decomposed;
    /// every mirrored frequency receives copied values and conjugated
    /// factors (`U(−θ) = conj(U(θ))`, `V(−θ) = Pᵀ·conj(V(θ))` with the
    /// stride aliasing permutation `P`) — exact by the symbol symmetry, so
    /// spectral transfer functions reconstruct `A(−θ)` bit-for-bit from
    /// them. Like [`Self::topk_svd`], always f64 regardless of the plan's
    /// [`Precision`].
    /// Grouped kernels are decomposed through the *embedded*
    /// block-diagonal symbol (`c_out × s²·c_in_total`) so the factors come
    /// out in operator coordinates; transposed kernels decompose the
    /// forward symbol and swap the `U`/`V` roles at packaging
    /// (`Aᴴ = VΣUᴴ`).
    pub fn full_svd(&self) -> FullSvd {
        let r = self.rank;
        let g = self.kernel.groups;
        let (cin, cin_total) = (self.kernel.c_in, self.kernel.c_in_total());
        // Forward-operator symbol shape; factor roles swap at packaging
        // when transposed.
        let (fwd_rows, fwd_cols) = (self.kernel.c_out, self.block_cols * g);
        let mut fa = FactorAssembly::new(self, r, fwd_rows, fwd_cols);
        let mut ws = self.checkout();
        let mut block = CMat::zeros(fwd_rows, fwd_cols);
        let mut health = SpectrumHealth::default();
        for ki in 0..self.nc {
            for kj in 0..self.mc {
                let f = ki * self.mc + kj;
                if self.fold && !self.freq_is_canonical(ki, kj) {
                    // The canonical partner precedes every mirrored
                    // frequency in row-major order: derive, don't solve.
                    let (mi, mj) = self.mirror_coords(ki, kj);
                    let fm = mi * self.mc + mj;
                    debug_assert!(fm < f, "mirror must already be decomposed");
                    fa.mirror_triplet(self, fm, f, mi, mj);
                    continue;
                }
                if g == 1 {
                    self.fill_block(ki, kj, 0, &mut ws);
                    block.data.copy_from_slice(&ws.block);
                } else {
                    // Embed the per-group blocks into the block-diagonal
                    // symbol: group gi's rows start at gi·block_rows, its
                    // columns sit at channel offset gi·c_in within each
                    // (a,b) alias column group.
                    for z in block.data.iter_mut() {
                        *z = C64::ZERO;
                    }
                    for gi in 0..g {
                        self.fill_block(ki, kj, gi, &mut ws);
                        for o in 0..self.block_rows {
                            for col in 0..self.block_cols {
                                let (ab, i) = (col / cin, col % cin);
                                block[(
                                    gi * self.block_rows + o,
                                    ab * cin_total + gi * cin + i,
                                )] = ws.block[o * self.block_cols + col];
                            }
                        }
                    }
                }
                // The full decomposition already runs the crate's most
                // robust path (f64 Jacobi with a fresh-restart retry), so
                // there is no further rung to escalate to: record the
                // certificate as-is.
                let dec = jacobi_svd::svd(&block);
                health.absorb(dec.cert.converged, dec.cert.restarted, 0, dec.cert.residual);
                fa.slot(f).copy_from_slice(&dec.s[..r]);
                fa.u[f] = dec.u;
                fa.v[f] = dec.v;
            }
        }
        self.restore(ws);
        let (sym_rows, sym_cols) = self.sym_shape();
        let FactorAssembly { values, u, v, .. } = fa;
        let sigma = self.spectrum_from_values_health(SpectrumRequest::Full, values, health);
        let (u, v) = if self.kernel.transposed { (v, u) } else { (u, v) };
        FullSvd { n: self.nc, m: self.mc, c_out: sym_rows, c_in: sym_cols, u, sigma, v }
    }

    /// Materialize the symbol grid in the plan's layout (stride 1 only) —
    /// the `s_F` stage of the timed Table III/IV pipelines and the input to
    /// spectral-transfer reconstruction.
    pub fn compute_symbols(&self) -> SymbolGrid {
        assert_eq!(self.stride, 1, "symbol grids are only defined for stride 1");
        assert!(
            self.kernel.groups == 1 && !self.kernel.transposed,
            "symbol grids are only materialized for forward ungrouped kernels \
             (grouped symbols are block-diagonal, adjoint symbols are their \
             conjugate transposes — take them per block from the plan instead)"
        );
        let (cout, cin) = (self.kernel.c_out, self.kernel.c_in);
        let block_len = cout * cin;
        let mut grid = SymbolGrid::zeros(self.n, self.m, cout, cin, self.layout);
        match self.layout {
            BlockLayout::BlockContiguous => {
                // The grid's buffer is already block-contiguous: fill it
                // directly, sharded over rows.
                let mut data = std::mem::take(&mut grid.data);
                self.symbols_into(&mut data);
                grid.data = data;
            }
            BlockLayout::PlanarStrided => {
                let mut buf = vec![C64::ZERO; self.n * self.m * block_len];
                self.symbols_into(&mut buf);
                scatter_shard(&mut grid, 0, self.n, &buf);
            }
        }
        grid
    }

    /// Fill `out` (`n·m·c_out·c_in` long) with all symbols in
    /// block-contiguous order, sharded across the plan's workers.
    fn symbols_into(&self, out: &mut [C64]) {
        debug_assert_eq!(self.stride, 1);
        let block_len = self.block_rows * self.block_cols;
        let threads = self.effective_threads();
        if threads <= 1 || self.nc <= 1 {
            let mut ws = self.checkout();
            self.symbol_rows(0, self.n, &mut ws, out);
            self.restore(ws);
            return;
        }
        let rows_per = self.n.div_ceil(threads);
        let row_elems = self.m * block_len;
        std::thread::scope(|scope| {
            let mut rest: &mut [C64] = out;
            let mut lo = 0usize;
            while lo < self.n {
                let hi = (lo + rows_per).min(self.n);
                let (head, tail) = std::mem::take(&mut rest).split_at_mut((hi - lo) * row_elems);
                rest = tail;
                scope.spawn(move || {
                    let mut ws = self.checkout();
                    self.symbol_rows(lo, hi, &mut ws, head);
                    self.restore(ws);
                });
                lo = hi;
            }
        });
    }

    /// Symbols for rows `[row_lo, row_hi)`, block-contiguous into `out`.
    fn symbol_rows(&self, row_lo: usize, row_hi: usize, ws: &mut Workspace, out: &mut [C64]) {
        let block_len = self.block_rows * self.block_cols;
        for ki in row_lo..row_hi {
            for kj in 0..self.mc {
                self.fill_block(ki, kj, 0, ws);
                let f = (ki - row_lo) * self.mc + kj;
                out[f * block_len..(f + 1) * block_len].copy_from_slice(&ws.block);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lfa::symbol::symbol_at;
    use crate::numeric::Pcg64;

    fn jacobi_block(b: &CMat) -> Vec<f64> {
        crate::linalg::jacobi_svd::singular_values(b)
    }

    #[test]
    fn plan_matches_per_frequency_reference() {
        let mut rng = Pcg64::seeded(600);
        let k = ConvKernel::random_he(3, 2, 3, 3, &mut rng);
        let (n, m) = (5, 7);
        let plan = SpectralPlan::new(&k, n, m, LfaOptions { threads: 1, ..Default::default() });
        let got = plan.execute();
        for ki in 0..n {
            for kj in 0..m {
                let want = jacobi_block(&symbol_at(&k, n, m, ki, kj));
                let at = got.at(ki * m + kj);
                for (a, b) in want.iter().take(at.len()).zip(at) {
                    assert!((a - b).abs() < 1e-12, "({ki},{kj}): {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn pool_reuse_is_deterministic() {
        let mut rng = Pcg64::seeded(601);
        let k = ConvKernel::random_he(4, 4, 3, 3, &mut rng);
        let plan = SpectralPlan::new(&k, 8, 8, LfaOptions { threads: 2, ..Default::default() });
        let a = plan.execute();
        let b = plan.execute();
        assert_eq!(a.values, b.values, "repeated execution must be bitwise identical");
    }

    #[test]
    fn shared_pool_plans_agree_with_private_pool_plans() {
        let mut rng = Pcg64::seeded(603);
        let k1 = ConvKernel::random_he(3, 2, 3, 3, &mut rng);
        let k2 = ConvKernel::random_he(3, 2, 3, 3, &mut rng);
        let opts = LfaOptions { threads: 1, ..Default::default() };
        let pool = Arc::new(WorkspacePool::for_block(3, 2, 9));
        let a = SpectralPlan::with_shared_pool(&k1, 6, 6, 1, opts, Arc::clone(&pool));
        let b = SpectralPlan::with_shared_pool(&k2, 4, 8, 1, opts, pool);
        assert_eq!(a.execute().values, SpectralPlan::new(&k1, 6, 6, opts).execute().values);
        assert_eq!(b.execute().values, SpectralPlan::new(&k2, 4, 8, opts).execute().values);
    }

    #[test]
    #[should_panic(expected = "does not cover")]
    fn mismatched_shared_pool_is_rejected() {
        let mut rng = Pcg64::seeded(604);
        let k = ConvKernel::random_he(4, 4, 3, 3, &mut rng);
        let pool = Arc::new(WorkspacePool::for_block(2, 2, 9));
        let _ = SpectralPlan::with_shared_pool(&k, 4, 4, 1, LfaOptions::default(), pool);
    }

    #[test]
    fn topk_matches_full_extremes() {
        let mut rng = Pcg64::seeded(605);
        let k = ConvKernel::random_he(5, 4, 3, 3, &mut rng);
        let plan = SpectralPlan::new(&k, 6, 6, LfaOptions { threads: 1, ..Default::default() });
        let full = plan.execute();
        let top = plan.execute_topk(2);
        assert_eq!(top.spectrum.rank_per_freq(), 2);
        assert!(!top.spectrum.is_full());
        let scale = full.sigma_max();
        for f in 0..plan.freqs() {
            let want = full.at(f);
            let got = top.spectrum.at(f);
            for j in 0..2 {
                assert!(
                    (want[j] - got[j]).abs() <= 1e-8 * scale,
                    "f={f} j={j}: {} vs {}",
                    got[j],
                    want[j]
                );
            }
        }
    }

    #[test]
    fn topk_warm_sweep_uses_fewer_iterations_than_cold() {
        // Channel count matters here: below c≈16 the Krylov loop exhausts
        // the whole space either way and warm/cold step counts tie. At
        // c=32 the warm hint reliably saves steps at every frequency.
        let mut rng = Pcg64::seeded(606);
        let k = ConvKernel::random_he(32, 32, 3, 3, &mut rng);
        let plan = SpectralPlan::new(&k, 6, 6, LfaOptions { threads: 1, ..Default::default() });
        let warm = plan.execute_topk(2);
        let mut cold_vals = vec![0.0f64; plan.topk_values_len(2)];
        let (cold_iters, _) = plan.execute_request_into(
            SpectrumRequest::TopK(2),
            SweepOptions::cold(),
            &mut cold_vals,
        );
        let scale = warm.spectrum.sigma_max();
        for (a, b) in warm.spectrum.values.iter().zip(&cold_vals) {
            assert!((a - b).abs() <= 2e-8 * scale, "{a} vs {b}");
        }
        assert!(
            warm.iterations < cold_iters,
            "warm {} vs cold {}",
            warm.iterations,
            cold_iters
        );
        assert!(warm.iterations_per_freq() >= 1.0);
    }

    #[test]
    fn topk_threaded_strips_match_serial_values() {
        let mut rng = Pcg64::seeded(607);
        let k = ConvKernel::random_he(4, 4, 3, 3, &mut rng);
        let plan = SpectralPlan::new(&k, 12, 12, LfaOptions { threads: 1, ..Default::default() });
        let serial = plan.execute_topk(3);
        let mut threaded = vec![0.0f64; plan.topk_values_len(3)];
        plan.execute_request_into(
            SpectrumRequest::TopK(3),
            SweepOptions::with_threads(3),
            &mut threaded,
        );
        let scale = serial.spectrum.sigma_max();
        for (a, b) in serial.spectrum.values.iter().zip(&threaded) {
            assert!((a - b).abs() <= 2e-8 * scale, "{a} vs {b}");
        }
    }

    #[test]
    fn topk_clamps_k_to_rank_and_supports_stride() {
        let mut rng = Pcg64::seeded(608);
        let k = ConvKernel::random_he(3, 2, 3, 3, &mut rng);
        let plan =
            SpectralPlan::with_stride(&k, 8, 8, 2, LfaOptions { threads: 1, ..Default::default() });
        // rank = min(3, 4·2) = 3; k = 9 clamps to 3.
        assert_eq!(plan.topk_per_freq(9), 3);
        let full = plan.execute();
        let top = plan.execute_topk(9);
        let scale = full.sigma_max();
        for (a, b) in full.values.iter().zip(&top.spectrum.values) {
            assert!((a - b).abs() <= 1e-8 * scale, "{a} vs {b}");
        }
    }

    #[test]
    fn topk_factors_reconstruct_best_rank_k() {
        let mut rng = Pcg64::seeded(609);
        let k = ConvKernel::random_he(4, 3, 3, 3, &mut rng);
        let plan = SpectralPlan::new(&k, 5, 5, LfaOptions { threads: 1, ..Default::default() });
        let fac = plan.topk_svd(2);
        assert_eq!(fac.k, 2);
        let full = plan.full_svd();
        for f in 0..plan.freqs() {
            // The truncated symbol must match the Eckart–Young truncation
            // built from the full SVD's top-2 triplets.
            let s = full.sigma.at(f);
            let u = &full.u[f];
            let v = &full.v[f];
            let mut us = CMat::zeros(u.rows, 2);
            for i in 0..u.rows {
                for j in 0..2 {
                    us[(i, j)] = u[(i, j)].scale(s[j]);
                }
            }
            let mut vr = CMat::zeros(v.rows, 2);
            for i in 0..v.rows {
                for j in 0..2 {
                    vr[(i, j)] = v[(i, j)];
                }
            }
            let want = us.matmul(&vr.hermitian());
            let got = fac.truncated_symbol(f);
            assert!(got.max_abs_diff(&want) < 1e-6, "f={f}");
        }
    }

    #[test]
    fn request_lengths_and_dispatch() {
        let mut rng = Pcg64::seeded(611);
        let k = ConvKernel::random_he(4, 4, 3, 3, &mut rng);
        let plan = SpectralPlan::new(&k, 4, 4, LfaOptions { threads: 1, ..Default::default() });
        assert_eq!(plan.request_values_len(SpectrumRequest::Full), plan.values_len());
        assert_eq!(plan.request_values_len(SpectrumRequest::TopK(2)), plan.topk_values_len(2));
        let mut full = vec![0.0f64; plan.values_len()];
        let (full_iters, full_health) =
            plan.execute_request_into(SpectrumRequest::Full, SweepOptions::default(), &mut full);
        assert_eq!(full_iters, 0);
        assert!(!full_health.is_degraded());
        let mut top = vec![0.0f64; plan.topk_values_len(1)];
        let (top_iters, top_health) =
            plan.execute_request_into(SpectrumRequest::TopK(1), SweepOptions::default(), &mut top);
        assert!(top_iters > 0);
        assert!(!top_health.is_degraded());
        assert!((top[0] - full[0]).abs() <= 1e-8 * full[0].max(1.0));
    }

    #[test]
    fn healthy_sweeps_certify_every_solved_frequency() {
        let mut rng = Pcg64::seeded(619);
        let k = ConvKernel::random_he(4, 3, 3, 3, &mut rng);
        let plan = SpectralPlan::new(&k, 6, 6, LfaOptions { threads: 1, ..Default::default() });
        let full = plan.execute();
        assert_eq!(full.health.converged_freqs as usize, plan.solved_freqs());
        assert_eq!(full.health.degraded_freqs, 0);
        assert_eq!(full.health.escalations, 0);
        assert!(full.health.worst_residual <= 1e-10);
        let top = plan.execute_topk(2);
        let h = top.spectrum.health;
        assert_eq!(
            (h.converged_freqs + h.retried_freqs) as usize,
            plan.solved_freqs(),
            "every solved frequency must carry a verdict"
        );
        assert_eq!(h.degraded_freqs, 0);
        let fac = plan.topk_svd(2);
        assert!(!fac.sigma.health.is_degraded());
        let dec = plan.full_svd();
        assert_eq!(dec.sigma.health.degraded_freqs, 0);
        assert!(dec.sigma.health.converged_freqs >= 1);
    }

    #[test]
    fn solved_freqs_counts_the_fundamental_domain() {
        let mut rng = Pcg64::seeded(612);
        let k = ConvKernel::random_he(2, 2, 3, 3, &mut rng);
        for &(n, m) in &[(4usize, 4usize), (5, 5), (5, 4), (4, 5), (1, 1), (2, 6), (8, 8)] {
            let plan = SpectralPlan::new(&k, n, m, LfaOptions { threads: 1, ..Default::default() });
            assert!(plan.folded());
            assert_eq!(plan.solved_rows(), n / 2 + 1, "{n}x{m}");
            assert_eq!(plan.solved_freqs(), crate::lfa::spectrum::folded_freqs(n, m), "{n}x{m}");
            let off = SpectralPlan::new(
                &k,
                n,
                m,
                LfaOptions { threads: 1, folding: Fold::Off, ..Default::default() },
            );
            assert!(!off.folded());
            assert_eq!(off.solved_rows(), n);
            assert_eq!(off.solved_freqs(), n * m);
        }
    }

    #[test]
    fn folded_execution_matches_unfolded() {
        let mut rng = Pcg64::seeded(613);
        let k = ConvKernel::random_he(3, 2, 3, 3, &mut rng);
        for &(n, m) in &[(6usize, 6usize), (5, 7), (4, 4)] {
            for threads in [1usize, 2] {
                let folded =
                    SpectralPlan::new(&k, n, m, LfaOptions { threads, ..Default::default() });
                let off = SpectralPlan::new(
                    &k,
                    n,
                    m,
                    LfaOptions { threads, folding: Fold::Off, ..Default::default() },
                );
                let a = folded.execute();
                let b = off.execute();
                let scale = b.sigma_max().max(1.0);
                for (x, y) in a.values.iter().zip(&b.values) {
                    assert!((x - y).abs() <= 1e-12 * scale, "{n}x{m} x{threads}: {x} vs {y}");
                }
            }
        }
    }

    #[test]
    fn folded_fold_rows_tiles_stitch_and_mirror_to_full_grid() {
        // The coordinator's folded tile shape: fundamental-domain row
        // strips via execute_request_rows_pooled + mirror_fill assembly.
        let mut rng = Pcg64::seeded(614);
        let k = ConvKernel::random_he(3, 3, 3, 3, &mut rng);
        let plan = SpectralPlan::new(&k, 9, 5, LfaOptions { threads: 1, ..Default::default() });
        let full = plan.execute();
        let r = plan.rank();
        let srows = plan.solved_rows();
        let mut stitched = vec![0.0f64; plan.values_len()];
        for (lo, hi) in [(0usize, 2usize), (2, 3), (3, srows)] {
            let chunk = &mut stitched[lo * 5 * r..hi * 5 * r];
            plan.execute_request_rows_pooled(SpectrumRequest::Full, lo, hi, chunk);
        }
        crate::lfa::spectrum::mirror_fill(9, 5, r, &mut stitched);
        assert_eq!(stitched, full.values, "folded tiles + mirror == folded execute");
    }

    #[test]
    fn folded_full_factors_reconstruct_mirrored_symbols() {
        let mut rng = Pcg64::seeded(615);
        let k = ConvKernel::random_he(3, 2, 3, 3, &mut rng);
        for &(n, m, s) in &[(6usize, 6usize, 1usize), (5, 4, 1), (8, 8, 2), (6, 6, 3)] {
            let plan = SpectralPlan::with_stride(
                &k,
                n,
                m,
                s,
                LfaOptions { threads: 1, ..Default::default() },
            );
            assert!(plan.folded());
            let svd = plan.full_svd();
            let (nc, mc) = (n / s, m / s);
            for ki in 0..nc {
                for kj in 0..mc {
                    let want = if s == 1 {
                        symbol_at(&k, n, m, ki, kj)
                    } else {
                        crate::lfa::stride::strided_symbol_at(&k, n, m, s, ki, kj)
                    };
                    let got = svd.symbol(ki * mc + kj);
                    assert!(
                        got.max_abs_diff(&want) < 1e-10,
                        "{n}x{m}/{s} ({ki},{kj}): {}",
                        got.max_abs_diff(&want)
                    );
                }
            }
        }
    }

    #[test]
    fn folded_topk_factors_match_unfolded_truncations() {
        let mut rng = Pcg64::seeded(616);
        let k = ConvKernel::random_he(4, 3, 3, 3, &mut rng);
        for &(n, m, s) in &[(5usize, 5usize, 1usize), (8, 8, 2)] {
            let folded = SpectralPlan::with_stride(
                &k,
                n,
                m,
                s,
                LfaOptions { threads: 1, ..Default::default() },
            );
            let off = SpectralPlan::with_stride(
                &k,
                n,
                m,
                s,
                LfaOptions { threads: 1, folding: Fold::Off, ..Default::default() },
            );
            let fa = folded.topk_svd(2);
            let fb = off.topk_svd(2);
            assert!(fa.iterations > 0 && fa.iterations <= fb.iterations);
            assert!((fa.total_energy - fb.total_energy).abs() <= 1e-9 * fb.total_energy);
            let scale = fb.sigma.sigma_max().max(1.0);
            for f in 0..folded.freqs() {
                // Truncated symbols are basis-independent: compare those,
                // not the (gauge-dependent) factors themselves.
                let ta = fa.truncated_symbol(f);
                let tb = fb.truncated_symbol(f);
                assert!(
                    ta.max_abs_diff(&tb) <= 1e-6 * scale,
                    "{n}x{m}/{s} f={f}: {}",
                    ta.max_abs_diff(&tb)
                );
            }
        }
    }

    #[test]
    fn precision_tiers_track_the_f64_full_sweep() {
        let mut rng = Pcg64::seeded(617);
        let k = ConvKernel::random_he(4, 3, 3, 3, &mut rng);
        let base = LfaOptions { threads: 1, ..Default::default() };
        let want = SpectralPlan::new(&k, 6, 6, base).execute();
        let scale = want.sigma_max().max(1.0);
        let f32p =
            SpectralPlan::new(&k, 6, 6, LfaOptions { precision: Precision::F32, ..base });
        assert_eq!(f32p.precision(), Precision::F32);
        assert_eq!(f32p.options().precision, Precision::F32);
        let got32 = f32p.execute();
        for (a, b) in want.values.iter().zip(&got32.values) {
            assert!((a - b).abs() <= 1e-4 * scale, "f32: {a} vs {b}");
        }
        let refp = SpectralPlan::new(
            &k,
            6,
            6,
            LfaOptions { precision: Precision::F32Refined, ..base },
        );
        let ref32 = refp.execute();
        for (a, b) in want.values.iter().zip(&ref32.values) {
            assert!((a - b).abs() <= 1e-12 * scale, "refined: {a} vs {b}");
        }
    }

    #[test]
    fn precision_tiers_track_the_f64_topk_sweep() {
        let mut rng = Pcg64::seeded(618);
        let k = ConvKernel::random_he(4, 3, 3, 3, &mut rng);
        let base = LfaOptions { threads: 1, ..Default::default() };
        for &(n, m, s) in &[(6usize, 6usize, 1usize), (8, 8, 2)] {
            let want = SpectralPlan::with_stride(&k, n, m, s, base).execute_topk(2);
            let scale = want.spectrum.sigma_max().max(1.0);
            let f32p = SpectralPlan::with_stride(
                &k,
                n,
                m,
                s,
                LfaOptions { precision: Precision::F32, ..base },
            );
            let got32 = f32p.execute_topk(2);
            assert!(got32.iterations > 0);
            for (a, b) in want.spectrum.values.iter().zip(&got32.spectrum.values) {
                assert!((a - b).abs() <= 2e-3 * scale, "{n}x{m}/{s} f32: {a} vs {b}");
            }
            let refp = SpectralPlan::with_stride(
                &k,
                n,
                m,
                s,
                LfaOptions { precision: Precision::F32Refined, ..base },
            );
            let refd = refp.execute_topk(2);
            for (a, b) in want.spectrum.values.iter().zip(&refd.spectrum.values) {
                assert!((a - b).abs() <= 1e-8 * scale, "{n}x{m}/{s} refined: {a} vs {b}");
            }
        }
    }

    #[test]
    fn materialized_symbols_match_fused_path() {
        let mut rng = Pcg64::seeded(602);
        let k = ConvKernel::random_he(2, 3, 3, 3, &mut rng);
        let plan = SpectralPlan::new(&k, 6, 4, LfaOptions { threads: 1, ..Default::default() });
        let grid = plan.compute_symbols();
        for ki in 0..6 {
            for kj in 0..4 {
                let want = symbol_at(&k, 6, 4, ki, kj);
                let gotb = grid.block(ki * 4 + kj);
                assert!(gotb.max_abs_diff(&want) < 1e-12, "({ki},{kj})");
            }
        }
    }

    /// A sink that only counts protocol events — proves `sweep_with`
    /// delivers every canonical frequency exactly once and every
    /// non-canonical frequency exactly one `mirror`.
    struct CountSink {
        scratch: Vec<f64>,
        committed: Vec<u32>,
        mirrored: Vec<u32>,
    }

    impl SpectrumSink for CountSink {
        fn slot(&mut self, _f: usize) -> &mut [f64] {
            &mut self.scratch
        }
        fn commit(&mut self, f: usize, _ki: usize, _kj: usize) {
            self.committed[f] += 1;
        }
        fn mirror(&mut self, src: usize, dst: usize) {
            assert!(self.committed[src] == 1 || self.mirrored[src] == 1, "mirror of unsolved {src}");
            self.mirrored[dst] += 1;
        }
    }

    #[test]
    fn sweep_with_covers_every_frequency_exactly_once() {
        let mut rng = Pcg64::seeded(620);
        let k = ConvKernel::random_he(3, 2, 3, 3, &mut rng);
        for &(n, m) in &[(6usize, 6usize), (5, 7), (4, 4), (1, 1)] {
            for fold in [Fold::Auto, Fold::Off] {
                let plan = SpectralPlan::new(
                    &k,
                    n,
                    m,
                    LfaOptions { threads: 1, folding: fold, ..Default::default() },
                );
                let mut sink = CountSink {
                    scratch: vec![0.0f64; plan.rank()],
                    committed: vec![0u32; plan.freqs()],
                    mirrored: vec![0u32; plan.freqs()],
                };
                plan.sweep_with(SpectrumRequest::Full, &mut sink);
                let solved: u32 = sink.committed.iter().sum();
                assert_eq!(solved as usize, plan.solved_freqs(), "{n}x{m} {fold:?}");
                for f in 0..plan.freqs() {
                    assert_eq!(
                        sink.committed[f] + sink.mirrored[f],
                        1,
                        "{n}x{m} {fold:?} f={f}: each frequency exactly once"
                    );
                }
            }
        }
    }

    #[test]
    fn density_census_matches_full_sweep() {
        let mut rng = Pcg64::seeded(621);
        let k = ConvKernel::random_he(4, 3, 3, 3, &mut rng);
        for &(n, m) in &[(6usize, 6usize), (5, 7)] {
            for fold in [Fold::Auto, Fold::Off] {
                let plan = SpectralPlan::new(
                    &k,
                    n,
                    m,
                    LfaOptions { threads: 1, folding: fold, ..Default::default() },
                );
                let full = plan.execute();
                let d = plan.density(DensityRequest { bins: 32, sample: 1 });
                assert_eq!(d.sample, 1);
                assert_eq!(d.covered_freqs, d.total_freqs, "census covers the grid");
                assert_eq!(d.sampled_fraction(), 1.0);
                assert_eq!(d.cdf_epsilon(), 0.0, "census carries no sampling error");
                assert_eq!(
                    d.count(),
                    (plan.freqs() * plan.rank()) as u64,
                    "census bins every singular value"
                );
                let scale = full.sigma_max().max(1.0);
                assert!((d.sigma_max - full.sigma_max()).abs() <= 1e-8 * scale);
                assert!((d.sigma_min_sampled - full.sigma_min()).abs() <= 1e-12 * scale);
                // The histogram CDF and the exact sorted values must agree
                // to within one bin width at every quantile.
                let sorted = full.sorted_desc();
                let bin_w = d.hi / 32.0;
                for &q in &[0.1f64, 0.25, 0.5, 0.75, 0.9] {
                    let est = d.quantile(q);
                    let idx = ((1.0 - q) * (sorted.len() - 1) as f64).round() as usize;
                    let exact = sorted[idx];
                    assert!(
                        (est - exact).abs() <= bin_w + 1e-9 * scale,
                        "{n}x{m} {fold:?} q={q}: {est} vs {exact}"
                    );
                }
                assert!(!d.is_degraded());
            }
        }
    }

    #[test]
    fn density_sampling_covers_sublattice_with_error_bars() {
        let mut rng = Pcg64::seeded(622);
        let k = ConvKernel::random_he(4, 4, 3, 3, &mut rng);
        let plan = SpectralPlan::new(&k, 16, 16, LfaOptions { threads: 1, ..Default::default() });
        let census = plan.density(DensityRequest { bins: 48, sample: 1 });
        let sampled = plan.density(DensityRequest { bins: 48, sample: 2 });
        assert_eq!(sampled.sample, 2);
        assert!(sampled.solved_freqs < census.solved_freqs);
        assert!(sampled.covered_freqs < sampled.total_freqs);
        let frac = sampled.sampled_fraction();
        assert!(frac > 0.15 && frac < 0.5, "quarter-ish sub-lattice, got {frac}");
        assert!(sampled.cdf_epsilon() > 0.0, "sampling must report an error bar");
        // Exact extremes survive sampling (the top-1 pass sweeps the whole
        // grid), and bulk quantiles stay within the error bar's bounds.
        let scale = census.sigma_max.max(1.0);
        assert!((sampled.sigma_max - census.sigma_max).abs() <= 1e-8 * scale);
        for &q in &[0.25f64, 0.5, 0.75] {
            let (lo, hi) = sampled.quantile_bounds(q);
            assert!(lo <= hi);
            let exact = census.quantile(q);
            let slack = 2.0 * census.hi / 48.0 + 1e-9 * scale;
            assert!(
                exact >= lo - slack && exact <= hi + slack,
                "q={q}: census {exact} outside sampled [{lo}, {hi}]"
            );
        }
        // Threaded accumulation covers the same sub-lattice and lands on
        // the same distribution (bin edges may shift by the ~1e-10 σ_max
        // difference between warm-start strip partitions).
        let threaded = plan.density_with(
            DensityRequest { bins: 48, sample: 2 },
            SweepOptions::with_threads(3),
        );
        assert_eq!(threaded.covered_freqs, sampled.covered_freqs);
        assert_eq!(threaded.solved_freqs, sampled.solved_freqs);
        assert!((threaded.sigma_max - sampled.sigma_max).abs() <= 1e-8 * scale);
        for &q in &[0.25f64, 0.5, 0.75] {
            assert!(
                (threaded.quantile(q) - sampled.quantile(q)).abs()
                    <= 1.5 * sampled.hi / 48.0 + 1e-9 * scale,
                "q={q}"
            );
        }
    }
}
