//! Execution backends for a [`SpectralPlan`].
//!
//! The plan owns the *what* (phase tables, workspaces, dual-grid geometry);
//! a [`SpectralBackend`] owns the *where*: same-thread, a scoped worker
//! pool, or (feature `pjrt`) an AOT-compiled XLA artifact driven through the
//! PJRT executor thread. All backends produce identical spectra; they exist
//! so callers can pick an execution strategy without touching the plan.

use super::plan::SpectralPlan;
use crate::error::Result;
use crate::lfa::spectrum::Spectrum;

/// A strategy for executing a [`SpectralPlan`].
pub trait SpectralBackend {
    /// Human-readable backend name (metrics, reports).
    fn name(&self) -> &'static str;

    /// Execute the plan, writing `plan.values_len()` singular values into
    /// `out` (frequency-major, descending per frequency).
    fn execute_into(&self, plan: &SpectralPlan, out: &mut [f64]) -> Result<()>;

    /// Execute the plan and package the result as a [`Spectrum`].
    fn execute(&self, plan: &SpectralPlan) -> Result<Spectrum> {
        let mut values = vec![0.0f64; plan.values_len()];
        self.execute_into(plan, &mut values)?;
        Ok(Spectrum {
            n: plan.coarse_rows(),
            m: plan.coarse_cols(),
            c_out: plan.block_shape().0,
            c_in: plan.block_shape().1,
            values,
        })
    }
}

/// Single-threaded native execution, regardless of the plan's thread hint.
/// The baseline for equivalence tests and the right choice inside an outer
/// parallel driver (e.g. the coordinator's worker pool).
pub struct NativeSerial;

impl SpectralBackend for NativeSerial {
    fn name(&self) -> &'static str {
        "native-serial"
    }

    fn execute_into(&self, plan: &SpectralPlan, out: &mut [f64]) -> Result<()> {
        plan.execute_into_threads(1, out);
        Ok(())
    }
}

/// Scoped-thread native execution with an explicit worker count (0 = auto =
/// `available_parallelism`).
pub struct NativeThreaded {
    pub threads: usize,
}

impl SpectralBackend for NativeThreaded {
    fn name(&self) -> &'static str {
        "native-threaded"
    }

    fn execute_into(&self, plan: &SpectralPlan, out: &mut [f64]) -> Result<()> {
        plan.execute_into_threads(super::resolve_threads(self.threads), out);
        Ok(())
    }
}

/// PJRT-backed execution: sweeps a matching AOT artifact over the dual grid
/// through the dedicated executor thread. Only meaningful for stride-1 plans
/// whose shape matches the artifact exactly.
#[cfg(feature = "pjrt")]
pub struct PjrtBackend {
    executor: crate::runtime::PjrtExecutor,
    artifact: crate::runtime::ArtifactSpec,
}

#[cfg(feature = "pjrt")]
impl PjrtBackend {
    pub fn new(
        executor: crate::runtime::PjrtExecutor,
        artifact: crate::runtime::ArtifactSpec,
    ) -> Self {
        Self { executor, artifact }
    }
}

#[cfg(feature = "pjrt")]
impl SpectralBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn execute_into(&self, plan: &SpectralPlan, out: &mut [f64]) -> Result<()> {
        use crate::bail;
        let a = &self.artifact;
        let (c_out, c_in) = plan.block_shape();
        let k = plan.kernel();
        if plan.stride() != 1
            || a.n != plan.coarse_rows()
            || a.m != plan.coarse_cols()
            || a.c_out != c_out
            || a.c_in != c_in
            || a.kh != k.kh
            || a.kw != k.kw
        {
            bail!(
                "artifact {} does not match the plan shape \
                 (n={}, m={}, c_out={}, c_in={}, kh={}, kw={})",
                a.name,
                plan.coarse_rows(),
                plan.coarse_cols(),
                c_out,
                c_in,
                k.kh,
                k.kw
            );
        }
        let weights: Vec<f32> = plan.kernel().data.iter().map(|&v| v as f32).collect();
        let values = self.executor.run_grid(a, &weights)?;
        if values.len() != out.len() {
            bail!("artifact {} returned {} values, expected {}", a.name, values.len(), out.len());
        }
        for (dst, &src) in out.iter_mut().zip(values.iter()) {
            *dst = src as f64;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::ConvKernel;
    use crate::lfa::svd::LfaOptions;
    use crate::numeric::Pcg64;

    #[test]
    fn serial_and_threaded_backends_agree() {
        let mut rng = Pcg64::seeded(610);
        let k = ConvKernel::random_he(3, 3, 3, 3, &mut rng);
        let plan = SpectralPlan::new(&k, 12, 12, LfaOptions::default());
        let a = NativeSerial.execute(&plan).unwrap();
        let b = NativeThreaded { threads: 3 }.execute(&plan).unwrap();
        assert_eq!(a.values, b.values);
        assert_eq!(NativeSerial.name(), "native-serial");
    }
}
