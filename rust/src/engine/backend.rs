//! Execution backends for a [`SpectralPlan`].
//!
//! The plan owns the *what* (phase tables, workspaces, dual-grid geometry);
//! a [`SpectralBackend`] owns the *where*: same-thread, a scoped worker
//! pool, or (feature `pjrt`) an AOT-compiled XLA artifact driven through the
//! PJRT executor thread. All backends produce identical spectra; they exist
//! so callers can pick an execution strategy without touching the plan.
//!
//! Backends also answer [`SpectrumRequest`]s: the native backends run the
//! warm-started top-k sweep (serially, or one contiguous frequency strip
//! per worker); the PJRT backend only serves full spectra (its AOT artifact
//! bakes the full per-frequency SVD in) and reports top-k unsupported.
//!
//! Conjugate-pair frequency folding ([`crate::lfa::Fold`]) is a **plan**
//! property: native backends inherit it transparently — their serial and
//! threaded partitioning runs over the plan's folded-index ranges (solved
//! fundamental-domain rows, weighted by solved-block count) whenever the
//! plan folds, so the ~2× SVD-work cut applies identically through every
//! native execution strategy. The PJRT artifact sweep always covers the
//! full grid (the AOT program bakes the dual-grid loop in).

use super::plan::{SpectralPlan, SweepOptions, TopKResult};
use super::{DensityRequest, SpectrumRequest};
use crate::bail;
use crate::error::Result;
use crate::lfa::spectrum::{SpectralDensity, Spectrum, SpectrumHealth};

/// A strategy for executing a [`SpectralPlan`].
pub trait SpectralBackend {
    /// Human-readable backend name (metrics, reports).
    fn name(&self) -> &'static str;

    /// Execute the plan, writing `plan.values_len()` singular values into
    /// `out` (frequency-major, descending per frequency). Returns the
    /// sweep's aggregated [`SpectrumHealth`] — backends that cannot
    /// certify (the PJRT artifact boundary carries no certificates) report
    /// the empty default, never a fabricated clean bill.
    fn execute_into(&self, plan: &SpectralPlan, out: &mut [f64]) -> Result<SpectrumHealth>;

    /// Execute `request` into `out` (`plan.request_values_len(request)`
    /// values); returns solver iteration steps spent (0 for the direct full
    /// path) and the sweep's health. The default implementation serves
    /// `Full` through [`Self::execute_into`] and rejects `TopK` — backends
    /// that can run the warm-started top-k sweep override it.
    fn execute_request_into(
        &self,
        plan: &SpectralPlan,
        request: SpectrumRequest,
        out: &mut [f64],
    ) -> Result<(u64, SpectrumHealth)> {
        match request {
            SpectrumRequest::Full => {
                let health = self.execute_into(plan, out)?;
                Ok((0, health))
            }
            SpectrumRequest::TopK(_) => {
                bail!("backend {} does not support partial-spectrum (top-k) requests", self.name())
            }
        }
    }

    /// Execute the plan and package the result as a [`Spectrum`]. Operator
    /// dimensions come from [`SpectralPlan::sym_shape`] — the full
    /// (block-diagonal, possibly adjoint) per-frequency shape, not the
    /// per-group solved block.
    fn execute(&self, plan: &SpectralPlan) -> Result<Spectrum> {
        let mut values = vec![0.0f64; plan.values_len()];
        let health = self.execute_into(plan, &mut values)?;
        let (c_out, c_in) = plan.sym_shape();
        Ok(Spectrum {
            n: plan.coarse_rows(),
            m: plan.coarse_cols(),
            c_out,
            c_in,
            per_freq: plan.rank(),
            values,
            health,
        })
    }

    /// Streaming singular-value density through this backend
    /// ([`SpectralPlan::density`]). The default implementation rejects —
    /// native backends override it (the density sweep needs the top-k
    /// extremes pass and the sink protocol, which an AOT artifact boundary
    /// cannot serve).
    fn execute_density(&self, plan: &SpectralPlan, req: DensityRequest) -> Result<SpectralDensity> {
        let _ = (plan, req);
        bail!("backend {} does not support density requests", self.name())
    }

    /// Top-`k` values per frequency through this backend.
    fn execute_topk(&self, plan: &SpectralPlan, k: usize) -> Result<TopKResult> {
        let ke = plan.topk_per_freq(k);
        let mut values = vec![0.0f64; plan.topk_values_len(k)];
        let (iterations, health) =
            self.execute_request_into(plan, SpectrumRequest::TopK(k), &mut values)?;
        let (c_out, c_in) = plan.sym_shape();
        Ok(TopKResult {
            spectrum: Spectrum {
                n: plan.coarse_rows(),
                m: plan.coarse_cols(),
                c_out,
                c_in,
                per_freq: ke,
                values,
                health,
            },
            iterations,
        })
    }
}

/// Single-threaded native execution, regardless of the plan's thread hint.
/// The baseline for equivalence tests and the right choice inside an outer
/// parallel driver (e.g. the coordinator's worker pool).
pub struct NativeSerial;

impl SpectralBackend for NativeSerial {
    fn name(&self) -> &'static str {
        "native-serial"
    }

    fn execute_into(&self, plan: &SpectralPlan, out: &mut [f64]) -> Result<SpectrumHealth> {
        let (_, health) =
            plan.execute_request_into(SpectrumRequest::Full, SweepOptions::with_threads(1), out);
        Ok(health)
    }

    fn execute_request_into(
        &self,
        plan: &SpectralPlan,
        request: SpectrumRequest,
        out: &mut [f64],
    ) -> Result<(u64, SpectrumHealth)> {
        Ok(plan.execute_request_into(request, SweepOptions::with_threads(1), out))
    }

    fn execute_density(&self, plan: &SpectralPlan, req: DensityRequest) -> Result<SpectralDensity> {
        Ok(plan.density_with(req, SweepOptions::with_threads(1)))
    }
}

/// Scoped-thread native execution with an explicit worker count (0 = auto =
/// `available_parallelism`).
pub struct NativeThreaded {
    pub threads: usize,
}

impl SpectralBackend for NativeThreaded {
    fn name(&self) -> &'static str {
        "native-threaded"
    }

    fn execute_into(&self, plan: &SpectralPlan, out: &mut [f64]) -> Result<SpectrumHealth> {
        let opts = SweepOptions::with_threads(super::resolve_threads(self.threads));
        let (_, health) = plan.execute_request_into(SpectrumRequest::Full, opts, out);
        Ok(health)
    }

    fn execute_request_into(
        &self,
        plan: &SpectralPlan,
        request: SpectrumRequest,
        out: &mut [f64],
    ) -> Result<(u64, SpectrumHealth)> {
        let opts = SweepOptions::with_threads(super::resolve_threads(self.threads));
        Ok(plan.execute_request_into(request, opts, out))
    }

    fn execute_density(&self, plan: &SpectralPlan, req: DensityRequest) -> Result<SpectralDensity> {
        let opts = SweepOptions::with_threads(super::resolve_threads(self.threads));
        Ok(plan.density_with(req, opts))
    }
}

/// PJRT-backed execution: sweeps a matching AOT artifact over the dual grid
/// through the dedicated executor thread. Only meaningful for stride-1 plans
/// whose shape matches the artifact exactly.
#[cfg(feature = "pjrt")]
pub struct PjrtBackend {
    executor: crate::runtime::PjrtExecutor,
    artifact: crate::runtime::ArtifactSpec,
}

#[cfg(feature = "pjrt")]
impl PjrtBackend {
    pub fn new(
        executor: crate::runtime::PjrtExecutor,
        artifact: crate::runtime::ArtifactSpec,
    ) -> Self {
        Self { executor, artifact }
    }
}

#[cfg(feature = "pjrt")]
impl SpectralBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn execute_into(&self, plan: &SpectralPlan, out: &mut [f64]) -> Result<SpectrumHealth> {
        let a = &self.artifact;
        let (c_out, c_in) = plan.block_shape();
        let k = plan.kernel();
        // AOT artifacts bake dense forward geometry in; structured plans
        // (grouped / dilated / transposed) never match one.
        if !k.is_dense()
            || plan.stride() != 1
            || a.n != plan.coarse_rows()
            || a.m != plan.coarse_cols()
            || a.c_out != c_out
            || a.c_in != c_in
            || a.kh != k.kh
            || a.kw != k.kw
        {
            bail!(
                "artifact {} does not match the plan shape \
                 (n={}, m={}, c_out={}, c_in={}, kh={}, kw={})",
                a.name,
                plan.coarse_rows(),
                plan.coarse_cols(),
                c_out,
                c_in,
                k.kh,
                k.kw
            );
        }
        let weights: Vec<f32> = plan.kernel().data.iter().map(|&v| v as f32).collect();
        let values = self.executor.run_grid(a, &weights)?;
        if values.len() != out.len() {
            bail!("artifact {} returned {} values, expected {}", a.name, values.len(), out.len());
        }
        for (dst, &src) in out.iter_mut().zip(values.iter()) {
            *dst = src as f64;
        }
        // No certificate evidence crosses the PJRT artifact boundary — the
        // AOT program returns bare values. Report the empty default rather
        // than a fabricated clean bill; native paths carry real evidence.
        Ok(SpectrumHealth::default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::ConvKernel;
    use crate::lfa::svd::LfaOptions;
    use crate::numeric::Pcg64;

    #[test]
    fn serial_and_threaded_backends_agree() {
        let mut rng = Pcg64::seeded(610);
        let k = ConvKernel::random_he(3, 3, 3, 3, &mut rng);
        let plan = SpectralPlan::new(&k, 12, 12, LfaOptions::default());
        let a = NativeSerial.execute(&plan).unwrap();
        let b = NativeThreaded { threads: 3 }.execute(&plan).unwrap();
        assert_eq!(a.values, b.values);
        assert!(!a.health.is_degraded() && !b.health.is_degraded());
        assert_eq!(a.health.converged_freqs, plan.solved_freqs() as u64);
        assert_eq!(NativeSerial.name(), "native-serial");
    }

    #[test]
    fn backends_fold_transparently() {
        use crate::lfa::svd::Fold;
        let mut rng = Pcg64::seeded(613);
        let k = ConvKernel::random_he(3, 3, 3, 3, &mut rng);
        let folded = SpectralPlan::new(&k, 10, 10, LfaOptions::default());
        let off_opts = LfaOptions { folding: Fold::Off, ..Default::default() };
        let off = SpectralPlan::new(&k, 10, 10, off_opts);
        assert!(folded.folded() && !off.folded());
        for backend in [&NativeSerial as &dyn SpectralBackend, &NativeThreaded { threads: 3 }] {
            let a = backend.execute(&folded).unwrap();
            let b = backend.execute(&off).unwrap();
            let scale = b.sigma_max().max(1.0);
            for (x, y) in a.values.iter().zip(&b.values) {
                assert!((x - y).abs() <= 1e-12 * scale, "{}: {x} vs {y}", backend.name());
            }
        }
    }

    #[test]
    fn native_backends_serve_density_requests() {
        let mut rng = Pcg64::seeded(614);
        let k = ConvKernel::random_he(3, 3, 3, 3, &mut rng);
        let plan = SpectralPlan::new(&k, 8, 8, LfaOptions::default());
        let req = DensityRequest { bins: 16, sample: 2 };
        let a = NativeSerial.execute_density(&plan, req).unwrap();
        let b = NativeThreaded { threads: 2 }.execute_density(&plan, req).unwrap();
        assert_eq!(a.covered_freqs, b.covered_freqs);
        assert!(a.sampled_fraction() < 1.0 && a.cdf_epsilon() > 0.0);
        let scale = a.sigma_max.max(1.0);
        assert!((a.sigma_max - b.sigma_max).abs() <= 1e-8 * scale);
        for &q in &[0.25f64, 0.5, 0.75] {
            assert!(
                (a.quantile(q) - b.quantile(q)).abs() <= 1.5 * a.hi / 16.0 + 1e-9 * scale,
                "q={q}"
            );
        }
    }

    #[test]
    fn backends_serve_topk_requests() {
        let mut rng = Pcg64::seeded(612);
        let k = ConvKernel::random_he(4, 4, 3, 3, &mut rng);
        let plan = SpectralPlan::new(&k, 8, 8, LfaOptions::default());
        let full = NativeSerial.execute(&plan).unwrap();
        let a = NativeSerial.execute_topk(&plan, 2).unwrap();
        let b = NativeThreaded { threads: 2 }.execute_topk(&plan, 2).unwrap();
        assert!(a.iterations > 0 && b.iterations > 0);
        let scale = full.sigma_max();
        for f in 0..plan.freqs() {
            for j in 0..2 {
                assert!((a.spectrum.at(f)[j] - full.at(f)[j]).abs() <= 1e-8 * scale);
                assert!((b.spectrum.at(f)[j] - full.at(f)[j]).abs() <= 1e-8 * scale);
            }
        }
    }
}
