//! [`ModelPlan`]: every conv layer of a model, planned once, executed as
//! one batched sweep.
//!
//! Whole-model workloads — spectral audits, training-loop clipping
//! (Senderovich et al.), compression sweeps — decompose the *same* layers
//! over and over. A `ModelPlan` amortizes the planning exactly once across
//! all of them:
//!
//! - every layer gets a [`SpectralPlan`] (phase tables, strided dual-grid
//!   geometry) built at construction, never per call;
//! - layers with equal per-frequency block shape (`c_out × s²·c_in` — the
//!   `(c_out, c_in, solver, layout)` grouping key with one options set) are
//!   **batched into a group sharing one [`WorkspacePool`]**, so a VGG-style
//!   stack with six equal-shape layers warms one scratch set, not six;
//! - `execute*` runs all layers back-to-back: serially as one group-major
//!   solver sweep, threaded as a single scoped fan-out over the whole
//!   model's frequency rows (one spawn round instead of one per layer), or
//!   through any [`SpectralBackend`] via [`ModelPlan::execute_with`].
//!
//! The whole-model entry points mirror the per-layer ones:
//! [`ModelPlan::execute`] (spectra), [`ModelPlan::full_svd_all`] (factors),
//! [`ModelPlan::clip_all`] (plan-reuse clipping for training loops) and
//! [`ModelPlan::lowrank_all`] (compression). The coordinator submits whole
//! models as one `ModelPlan` (see `coordinator::scheduler::submit_model`),
//! and the `audit-model` CLI subcommand drives one directly.

use super::backend::SpectralBackend;
use super::plan::SpectralPlan;
use super::workspace::{Workspace, WorkspacePool};
use crate::bail;
use crate::error::Result;
use crate::lfa::spectrum::{FullSvd, Spectrum};
use crate::lfa::svd::LfaOptions;
use crate::model::config::ModelConfig;
use crate::spectral::clip::{clip_with_plan, ClipResult};
use crate::spectral::lowrank::{compress_from_svd, LowRankConv};
use std::sync::Arc;

/// One planned layer of a [`ModelPlan`].
struct LayerEntry {
    name: String,
    plan: SpectralPlan,
    /// Start of this layer's values in the whole-model buffer. Offsets are
    /// assigned in group-major order so the batched sweep writes the buffer
    /// front to back.
    offset: usize,
    /// Index into the plan's equal-shape groups.
    group: usize,
}

/// A contiguous run of one layer's coarse frequency rows — the unit the
/// threaded whole-model sweep partitions.
struct Span {
    layer: usize,
    lo: usize,
    hi: usize,
    /// Singular values this span produces.
    len: usize,
}

/// The spectrum of one layer, as produced by a whole-model execution.
#[derive(Clone, Debug)]
pub struct LayerSpectrum {
    pub name: String,
    pub spectrum: Spectrum,
}

/// Per-layer spectra of a whole model, plus aggregate views.
#[derive(Clone, Debug)]
pub struct ModelSpectra {
    /// Model name (from the config).
    pub model: String,
    /// Layers in original model order.
    pub layers: Vec<LayerSpectrum>,
}

impl ModelSpectra {
    /// Total singular values across all layers.
    pub fn num_values(&self) -> usize {
        self.layers.iter().map(|l| l.spectrum.num_values()).sum()
    }

    /// Largest singular value anywhere in the model.
    pub fn sigma_max(&self) -> f64 {
        self.layers.iter().map(|l| l.spectrum.sigma_max()).fold(0.0, f64::max)
    }

    /// Smallest singular value anywhere in the model.
    pub fn sigma_min(&self) -> f64 {
        self.layers.iter().map(|l| l.spectrum.sigma_min()).fold(f64::INFINITY, f64::min)
    }

    /// Composition bound on the network's Lipschitz constant: the product
    /// of per-layer spectral norms (tight only for linear chains, but the
    /// standard certified bound — Szegedy et al. 2014).
    pub fn lipschitz_upper_bound(&self) -> f64 {
        self.layers.iter().map(|l| l.spectrum.sigma_max()).product()
    }

    /// Look a layer up by name.
    pub fn layer(&self, name: &str) -> Option<&LayerSpectrum> {
        self.layers.iter().find(|l| l.name == name)
    }
}

/// A whole model planned once: per-layer [`SpectralPlan`]s, equal-shape
/// groups sharing workspace pools, and batched whole-model execution.
pub struct ModelPlan {
    name: String,
    /// Layers in original model order.
    layers: Vec<LayerEntry>,
    /// Layer indices in buffer (group-major) order.
    exec_order: Vec<usize>,
    /// Equal-shape groups: member layer indices, original order within.
    groups: Vec<Vec<usize>>,
    total_values: usize,
    threads: usize,
}

impl ModelPlan {
    /// Plan every layer of `model` once. Layers are materialized from the
    /// config's seed (the paper's "random weight tensors"), grouped by
    /// per-frequency block shape, and each group shares one workspace pool.
    /// `opts.threads` drives the whole-model sweep; the per-layer plans are
    /// built serial (the model plan owns the parallelism).
    pub fn build(model: &ModelConfig, opts: LfaOptions) -> Result<ModelPlan> {
        if model.layers.is_empty() {
            bail!("model {:?} has no layers to plan", model.name);
        }
        // Validate and compute per-layer block shapes + tap counts.
        let mut shapes: Vec<(usize, usize, usize)> = Vec::with_capacity(model.layers.len());
        for l in &model.layers {
            if l.stride == 0 || l.height % l.stride != 0 || l.width % l.stride != 0 {
                bail!(
                    "layer {:?}: stride {} must be nonzero and divide the {}x{} grid",
                    l.name,
                    l.stride,
                    l.height,
                    l.width
                );
            }
            shapes.push((l.c_out, l.stride * l.stride * l.c_in, l.kh * l.kw));
        }
        // Group layers with equal block shape. Solver and layout are uniform
        // across one plan's options, so the (c_out, c_in, solver, layout)
        // batching key reduces to the block shape here; tap counts may
        // differ within a group and the pool is sized for the largest.
        let mut keys: Vec<(usize, usize)> = Vec::new();
        let mut groups: Vec<Vec<usize>> = Vec::new();
        for (i, &(rows, cols, _)) in shapes.iter().enumerate() {
            match keys.iter().position(|&k| k == (rows, cols)) {
                Some(g) => groups[g].push(i),
                None => {
                    keys.push((rows, cols));
                    groups.push(vec![i]);
                }
            }
        }
        let mut group_of = vec![0usize; model.layers.len()];
        let mut pools: Vec<Arc<WorkspacePool>> = Vec::with_capacity(groups.len());
        for (g, members) in groups.iter().enumerate() {
            let (rows, cols) = keys[g];
            let ntaps = members.iter().map(|&i| shapes[i].2).max().unwrap_or(1);
            pools.push(Arc::new(WorkspacePool::for_block(rows, cols, ntaps)));
            for &i in members {
                group_of[i] = g;
            }
        }
        // Build the per-layer plans against the shared pools.
        let layer_opts = LfaOptions { threads: 1, ..opts };
        let mut plans: Vec<SpectralPlan> = Vec::with_capacity(model.layers.len());
        for (i, l) in model.layers.iter().enumerate() {
            let kernel = l.materialize(model.seed);
            plans.push(SpectralPlan::with_shared_pool(
                &kernel,
                l.height,
                l.width,
                l.stride,
                layer_opts,
                Arc::clone(&pools[group_of[i]]),
            ));
        }
        // Assign buffer offsets in group-major order: one batched sweep per
        // group writes the whole-model buffer front to back.
        let mut offsets = vec![0usize; plans.len()];
        let mut exec_order = Vec::with_capacity(plans.len());
        let mut offset = 0usize;
        for members in &groups {
            for &i in members {
                offsets[i] = offset;
                offset += plans[i].values_len();
                exec_order.push(i);
            }
        }
        let mut layers = Vec::with_capacity(plans.len());
        for (i, plan) in plans.into_iter().enumerate() {
            layers.push(LayerEntry {
                name: model.layers[i].name.clone(),
                plan,
                offset: offsets[i],
                group: group_of[i],
            });
        }
        Ok(ModelPlan {
            name: model.name.clone(),
            layers,
            exec_order,
            groups,
            total_values: offset,
            threads: opts.threads,
        })
    }

    /// Model name (from the config).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Number of planned layers.
    pub fn layer_count(&self) -> usize {
        self.layers.len()
    }

    /// Name of layer `i` (original model order).
    pub fn layer_name(&self, i: usize) -> &str {
        &self.layers[i].name
    }

    /// The planned pipeline of layer `i`.
    pub fn layer_plan(&self, i: usize) -> &SpectralPlan {
        &self.layers[i].plan
    }

    /// Start of layer `i`'s values in the whole-model buffer.
    pub fn layer_offset(&self, i: usize) -> usize {
        self.layers[i].offset
    }

    /// Number of equal-shape groups (== distinct block shapes).
    pub fn group_count(&self) -> usize {
        self.groups.len()
    }

    /// Member layer indices of group `g`.
    pub fn group_members(&self, g: usize) -> &[usize] {
        &self.groups[g]
    }

    /// Total singular values across all layers — the length of the buffer
    /// [`Self::execute_into`] fills.
    pub fn values_len(&self) -> usize {
        self.total_values
    }

    /// Worker count a whole-model sweep will use (0 in options = auto).
    pub fn effective_threads(&self) -> usize {
        let freqs: usize = self.layers.iter().map(|l| l.plan.freqs()).sum();
        // Tiny models: thread spawn overhead dominates the whole pipeline.
        if freqs < 64 {
            return 1;
        }
        let total_rows: usize = self.layers.iter().map(|l| l.plan.coarse_rows()).sum();
        super::resolve_threads(self.threads).min(total_rows.max(1))
    }

    /// Execute every layer into a caller-provided whole-model buffer
    /// (`values_len()` long). Serially this is one group-major batched
    /// sweep — a single workspace checkout per group, zero heap allocation
    /// per frequency. Threaded, the model's frequency rows are partitioned
    /// across one scoped worker fan-out (not one per layer).
    pub fn execute_into(&self, out: &mut [f64]) {
        assert_eq!(out.len(), self.total_values, "output buffer length mismatch");
        let threads = self.effective_threads();
        if threads <= 1 {
            for members in &self.groups {
                let mut ws = self.layers[members[0]].plan.checkout();
                for &i in members {
                    let l = &self.layers[i];
                    let slice = &mut out[l.offset..l.offset + l.plan.values_len()];
                    l.plan.execute_rows(0, l.plan.coarse_rows(), &mut ws, slice);
                }
                self.layers[members[0]].plan.restore(ws);
            }
            return;
        }
        // Cut layers into row spans (buffer order), then hand contiguous
        // runs of roughly equal value counts to each worker.
        let spans_target = (threads * 4).max(1);
        let total_rows: usize = self.layers.iter().map(|l| l.plan.coarse_rows()).sum();
        let rows_per = total_rows.div_ceil(spans_target).max(1);
        let mut spans: Vec<Span> = Vec::new();
        for &i in &self.exec_order {
            let plan = &self.layers[i].plan;
            let nc = plan.coarse_rows();
            let row_vals = plan.coarse_cols() * plan.rank();
            let mut lo = 0usize;
            while lo < nc {
                let hi = (lo + rows_per).min(nc);
                spans.push(Span { layer: i, lo, hi, len: (hi - lo) * row_vals });
                lo = hi;
            }
        }
        let target = self.total_values.div_ceil(threads).max(1);
        std::thread::scope(|scope| {
            let mut rest: &mut [f64] = out;
            let mut s0 = 0usize;
            while s0 < spans.len() {
                let mut s1 = s0;
                let mut acc = 0usize;
                while s1 < spans.len() && acc < target {
                    acc += spans[s1].len;
                    s1 += 1;
                }
                let (head, tail) = std::mem::take(&mut rest).split_at_mut(acc);
                rest = tail;
                let chunk = &spans[s0..s1];
                scope.spawn(move || self.execute_spans(chunk, head));
                s0 = s1;
            }
        });
    }

    /// Worker body: execute a contiguous run of spans, checking one
    /// workspace out per group transition (spans arrive group-major, so a
    /// worker crossing layers inside one group keeps its scratch).
    fn execute_spans(&self, spans: &[Span], out: &mut [f64]) {
        let mut cur_group = usize::MAX;
        let mut ws: Option<Workspace> = None;
        let mut pos = 0usize;
        for s in spans {
            let l = &self.layers[s.layer];
            if l.group != cur_group {
                if let Some(w) = ws.take() {
                    self.group_pool(cur_group).restore(w);
                }
                ws = Some(l.plan.checkout());
                cur_group = l.group;
            }
            let w = ws.as_mut().expect("workspace checked out above");
            l.plan.execute_rows(s.lo, s.hi, w, &mut out[pos..pos + s.len]);
            pos += s.len;
        }
        if let Some(w) = ws.take() {
            self.group_pool(cur_group).restore(w);
        }
    }

    fn group_pool(&self, g: usize) -> &Arc<WorkspacePool> {
        self.layers[self.groups[g][0]].plan.workspace_pool()
    }

    /// Execute the whole model and package per-layer spectra.
    pub fn execute(&self) -> ModelSpectra {
        let mut values = vec![0.0f64; self.total_values];
        self.execute_into(&mut values);
        self.spectra_from_flat(&values)
    }

    /// Execute every layer back-to-back through an explicit backend
    /// (serial, threaded, or — feature `pjrt` — an AOT artifact sweep).
    pub fn execute_with(&self, backend: &dyn SpectralBackend) -> Result<ModelSpectra> {
        let mut values = vec![0.0f64; self.total_values];
        for &i in &self.exec_order {
            let l = &self.layers[i];
            backend.execute_into(&l.plan, &mut values[l.offset..l.offset + l.plan.values_len()])?;
        }
        Ok(self.spectra_from_flat(&values))
    }

    /// Split a flat whole-model buffer (as filled by [`Self::execute_into`])
    /// into per-layer spectra, original model order.
    pub fn spectra_from_flat(&self, values: &[f64]) -> ModelSpectra {
        assert_eq!(values.len(), self.total_values, "flat buffer length mismatch");
        let layers = self
            .layers
            .iter()
            .map(|l| {
                let p = &l.plan;
                LayerSpectrum {
                    name: l.name.clone(),
                    spectrum: Spectrum {
                        n: p.coarse_rows(),
                        m: p.coarse_cols(),
                        c_out: p.block_shape().0,
                        c_in: p.block_shape().1,
                        values: values[l.offset..l.offset + p.values_len()].to_vec(),
                    },
                }
            })
            .collect();
        ModelSpectra { model: self.name.clone(), layers }
    }

    /// Full per-frequency SVD of every layer (original model order).
    pub fn full_svd_all(&self) -> Vec<FullSvd> {
        self.layers.iter().map(|l| l.plan.execute_full()).collect()
    }

    /// Clip every layer's spectrum at `cap` against the held plans — the
    /// training-loop shape: plan once at startup, clip every step without
    /// re-planning. Only defined for stride-1 layers (the least-squares
    /// kernel projection needs the dense symbol grid).
    pub fn clip_all(&self, cap: f64) -> Result<Vec<ClipResult>> {
        for l in &self.layers {
            if l.plan.stride() != 1 {
                bail!(
                    "clip_all: layer {:?} has stride {} — kernel projection is only \
                     defined for dense (stride-1) layers",
                    l.name,
                    l.plan.stride()
                );
            }
        }
        Ok(self.layers.iter().map(|l| clip_with_plan(&l.plan, cap)).collect())
    }

    /// Rank-`r` truncation of every layer (Eckart–Young optimal per
    /// frequency), original model order.
    pub fn lowrank_all(&self, rank: usize) -> Vec<LowRankConv> {
        self.layers.iter().map(|l| compress_from_svd(&l.plan.execute_full(), rank)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;

    const MIXED: &str = r#"
name = "mixed"
seed = 11

[[layer]]
name   = "a1"
c_in   = 3
c_out  = 4
height = 8
width  = 8

[[layer]]
name   = "b"
c_in   = 2
c_out  = 3
height = 6
width  = 6

[[layer]]
name   = "a2"
c_in   = 3
c_out  = 4
height = 4
width  = 8
"#;

    #[test]
    fn groups_equal_shapes_and_preserves_order() {
        let model = ModelConfig::parse(MIXED).unwrap();
        let mp = ModelPlan::build(&model, LfaOptions { threads: 1, ..Default::default() })
            .unwrap();
        assert_eq!(mp.layer_count(), 3);
        assert_eq!(mp.group_count(), 2, "a1 and a2 share a 4x3 group");
        assert_eq!(mp.group_members(0), &[0, 2]);
        assert_eq!(mp.group_members(1), &[1]);
        assert_eq!(
            mp.values_len(),
            model.layers.iter().map(|l| l.num_values()).sum::<usize>()
        );
        let spectra = mp.execute();
        // Spectra come back in original model order regardless of grouping.
        assert_eq!(spectra.layers[0].name, "a1");
        assert_eq!(spectra.layers[1].name, "b");
        assert_eq!(spectra.layers[2].name, "a2");
        assert_eq!(spectra.num_values(), mp.values_len());
        assert!(spectra.sigma_max() > 0.0);
        assert!(spectra.lipschitz_upper_bound() > 0.0);
        assert!(spectra.layer("b").is_some());
        assert!(spectra.layer("nope").is_none());
    }

    #[test]
    fn empty_model_is_rejected() {
        let model = ModelConfig {
            name: "empty".into(),
            seed: 0,
            layers: Vec::new(),
        };
        assert!(ModelPlan::build(&model, LfaOptions::default()).is_err());
    }
}
