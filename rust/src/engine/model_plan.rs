//! [`ModelPlan`]: every conv layer of a model, planned once, executed as
//! one batched sweep.
//!
//! Whole-model workloads — spectral audits, training-loop clipping
//! (Senderovich et al.), compression sweeps — decompose the *same* layers
//! over and over. A `ModelPlan` amortizes the planning exactly once across
//! all of them:
//!
//! - every layer gets a [`SpectralPlan`] (phase tables, strided dual-grid
//!   geometry) built at construction, never per call;
//! - layers with equal per-frequency **solved** block shape
//!   (`c_out/g × s²·c_in/g` — grouped layers solve their `g` diagonal
//!   blocks independently, so the per-group shape is the scratch shape)
//!   are **batched into a group sharing one [`WorkspacePool`]**, so a
//!   VGG-style stack with six equal-shape layers warms one scratch set,
//!   not six;
//! - `execute*` runs all layers back-to-back: serially as one group-major
//!   solver sweep, threaded as a single scoped fan-out over the whole
//!   model's frequency rows (one spawn round instead of one per layer), or
//!   through any [`SpectralBackend`] via [`ModelPlan::execute_with`].
//!
//! The whole-model entry points mirror the per-layer ones:
//! [`ModelPlan::execute`] (spectra), [`ModelPlan::top_k_all`] (partial
//! spectra via the warm-started Krylov sweep), [`ModelPlan::full_svd_all`]
//! (factors), [`ModelPlan::clip_all`] (plan-reuse clipping for training
//! loops, screened by a cheap top-1 sweep) and [`ModelPlan::lowrank_all`]
//! (compression). The coordinator submits whole models as one `ModelPlan`
//! (see `coordinator::scheduler::submit_model`), and the `audit-model` CLI
//! subcommand drives one directly.

use super::backend::SpectralBackend;
use super::cache::{Signature, SpectralCache};
use super::plan::{SpectralPlan, SweepOptions};
use super::workspace::{Workspace, WorkspacePool};
use super::{DensityRequest, SpectrumRequest};
use crate::bail;
use crate::error::{Error, Result};
use crate::lfa::spectrum::{mirror_fill, FullSvd, SpectralDensity, Spectrum, SpectrumHealth};
use crate::lfa::svd::LfaOptions;
use crate::model::config::ModelConfig;
use crate::spectral::clip::{clip_with_plan, unclipped_result, ClipResult};
use crate::spectral::lowrank::{compress_from_svd, LowRankConv};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// One planned layer of a [`ModelPlan`].
struct LayerEntry {
    name: String,
    plan: Arc<SpectralPlan>,
    /// This layer's plan signature when the model was built against a
    /// [`SpectralCache`] (`None` for plain builds) — result signatures
    /// derive from it without re-hashing the weight tensor.
    plan_key: Option<Signature>,
    /// Start of this layer's values in the whole-model buffer. Offsets are
    /// assigned in group-major order so the batched sweep writes the buffer
    /// front to back.
    offset: usize,
    /// Index into the plan's equal-shape groups.
    group: usize,
}

/// A contiguous run of one layer's **solved** coarse frequency rows (the
/// fundamental-domain rows when the layer's plan folds) — the unit the
/// threaded whole-model sweep partitions.
struct Span {
    layer: usize,
    lo: usize,
    hi: usize,
    /// Singular values this span produces.
    len: usize,
    /// Absolute start of this span's values in the whole-model buffer.
    /// Folded layers leave a gap between their last span and the next
    /// layer's first (the mirrored bottom half, filled at assembly).
    offset: usize,
}

/// The spectrum of one layer, as produced by a whole-model execution.
/// The spectrum is shared (`Arc`) so cached executions can hand the same
/// buffer to every consumer without copying.
#[derive(Clone, Debug)]
pub struct LayerSpectrum {
    pub name: String,
    pub spectrum: Arc<Spectrum>,
}

/// The spectral density of one layer, as produced by a whole-model
/// density sweep ([`ModelPlan::density_all`]). Shared (`Arc`) for the
/// same reason as [`LayerSpectrum`]: cached sweeps hand one histogram to
/// every consumer.
#[derive(Clone, Debug)]
pub struct LayerDensity {
    pub name: String,
    /// Streaming singular-value histogram with coverage error bars.
    pub density: Arc<SpectralDensity>,
    /// Served straight from the result cache — zero frequencies solved.
    pub cached: bool,
}

/// Per-layer spectra of a whole model, plus aggregate views.
#[derive(Clone, Debug)]
pub struct ModelSpectra {
    /// Model name (from the config).
    pub model: String,
    /// Layers in original model order.
    pub layers: Vec<LayerSpectrum>,
}

/// Whole-model top-k result: per-layer **partial** spectra (the `k`
/// extreme values per frequency) plus the solver effort.
/// Everything that only consumes extremes —
/// [`ModelSpectra::sigma_max`], [`ModelSpectra::lipschitz_upper_bound`] —
/// reads identically off this as off a full execution.
#[derive(Clone, Debug)]
pub struct ModelTopK {
    /// Per-layer partial spectra (`per_freq == k`, clamped per layer).
    pub spectra: ModelSpectra,
    /// The requested `k` (individual layers clamp to their rank).
    pub k: usize,
    /// Total solver iteration steps across every layer and frequency.
    pub iterations: u64,
}

/// Outcome of a cache-mediated whole-model execution
/// ([`ModelPlan::execute_cached`] / [`ModelPlan::top_k_all_cached`]):
/// the spectra plus what the cache saved.
#[derive(Clone, Debug)]
pub struct CachedExecution {
    /// Per-layer spectra, original model order (cache hits share their
    /// buffer with the cache; recomputed layers were inserted into it).
    pub spectra: ModelSpectra,
    /// Solver iteration steps spent on recomputed layers (0 for full
    /// spectra and for all-hit sweeps).
    pub iterations: u64,
    /// Layers served straight from the result cache.
    pub cache_hits: usize,
    /// Block SVDs actually performed — 0 when every layer hit.
    pub freqs_solved: usize,
    /// Result-cache evictions triggered by storing this sweep's results.
    pub evictions: u64,
}

impl ModelSpectra {
    /// Total singular values across all layers.
    pub fn num_values(&self) -> usize {
        self.layers.iter().map(|l| l.spectrum.num_values()).sum()
    }

    /// Largest singular value anywhere in the model.
    pub fn sigma_max(&self) -> f64 {
        self.layers.iter().map(|l| l.spectrum.sigma_max()).fold(0.0, f64::max)
    }

    /// Smallest singular value anywhere in the model. NaN when any layer
    /// holds a partial (top-k) spectrum — the retained extremes do not
    /// span the operator's smallest value (`f64::min` would silently drop
    /// the per-layer NaNs, so the guard lives here too).
    pub fn sigma_min(&self) -> f64 {
        if self.layers.iter().any(|l| l.spectrum.is_partial()) {
            return f64::NAN;
        }
        self.layers.iter().map(|l| l.spectrum.sigma_min()).fold(f64::INFINITY, f64::min)
    }

    /// Composition bound on the network's Lipschitz constant: the product
    /// of per-layer spectral norms (tight only for linear chains, but the
    /// standard certified bound — Szegedy et al. 2014).
    pub fn lipschitz_upper_bound(&self) -> f64 {
        self.layers.iter().map(|l| l.spectrum.sigma_max()).product()
    }

    /// Look a layer up by name.
    pub fn layer(&self, name: &str) -> Option<&LayerSpectrum> {
        self.layers.iter().find(|l| l.name == name)
    }

    /// Whole-model numerical health: every layer's [`SpectrumHealth`]
    /// merged into one evidence record (counts add, worst residual wins).
    pub fn health(&self) -> SpectrumHealth {
        let mut h = SpectrumHealth::default();
        for l in &self.layers {
            h.merge(&l.spectrum.health);
        }
        h
    }

    /// True when any layer's spectrum is still flagged degraded after the
    /// escalation ladder ran out of rungs.
    pub fn is_degraded(&self) -> bool {
        self.layers.iter().any(|l| l.spectrum.health.is_degraded())
    }

    /// Names of the degraded layers, original order (empty when healthy).
    pub fn degraded_layers(&self) -> Vec<&str> {
        self.layers
            .iter()
            .filter(|l| l.spectrum.health.is_degraded())
            .map(|l| l.name.as_str())
            .collect()
    }
}

/// A whole model planned once: per-layer [`SpectralPlan`]s, equal-shape
/// groups sharing workspace pools, and batched whole-model execution.
pub struct ModelPlan {
    name: String,
    /// Layers in original model order.
    layers: Vec<LayerEntry>,
    /// Layer indices in buffer (group-major) order.
    exec_order: Vec<usize>,
    /// Equal-shape groups: member layer indices, original order within.
    groups: Vec<Vec<usize>>,
    total_values: usize,
    threads: usize,
}

impl ModelPlan {
    /// Plan every layer of `model` once. Layers are materialized from the
    /// config's seed (the paper's "random weight tensors"), grouped by
    /// per-frequency block shape, and each group shares one workspace pool.
    /// `opts.threads` drives the whole-model sweep; the per-layer plans are
    /// built serial (the model plan owns the parallelism).
    pub fn build(model: &ModelConfig, opts: LfaOptions) -> Result<ModelPlan> {
        Self::build_with_cache(model, opts, None)
    }

    /// [`Self::build`] drawing layer plans from (and populating) a
    /// [`SpectralCache`]'s plan cache: layers whose plan signature —
    /// weight bits, geometry, options — matches a cached plan reuse it
    /// (phase tables *and* warmed workspace pool) instead of re-planning.
    /// Rebuilding the same model (the repeat-audit loop) re-plans nothing;
    /// after a training step only the mutated layers re-plan.
    pub fn build_cached(
        model: &ModelConfig,
        opts: LfaOptions,
        cache: &SpectralCache,
    ) -> Result<ModelPlan> {
        Self::build_with_cache(model, opts, Some(cache))
    }

    fn build_with_cache(
        model: &ModelConfig,
        opts: LfaOptions,
        cache: Option<&SpectralCache>,
    ) -> Result<ModelPlan> {
        if model.layers.is_empty() {
            bail!("model {:?} has no layers to plan", model.name);
        }
        // Validate and compute per-layer block shapes + tap counts.
        let mut shapes: Vec<(usize, usize, usize)> = Vec::with_capacity(model.layers.len());
        for l in &model.layers {
            if l.stride == 0 || l.height % l.stride != 0 || l.width % l.stride != 0 {
                bail!(
                    "layer {:?}: stride {} must be nonzero and divide the {}x{} grid",
                    l.name,
                    l.stride,
                    l.height,
                    l.width
                );
            }
            // The pool covers the per-frequency **solved** block — the
            // per-group shape for grouped layers (the plan solves the g
            // diagonal blocks independently), so a grouped and a dense
            // layer with the same per-group shape share scratch.
            shapes.push((
                l.c_out / l.groups,
                l.stride * l.stride * (l.c_in / l.groups),
                l.kh * l.kw,
            ));
        }
        // Per-layer plans are built serial; the model plan owns the
        // parallelism. Cached plans are looked up by the plan signature —
        // computed once per layer (it hashes the whole weight tensor
        // through both FNV streams) and reused when freshly built plans
        // are stored below.
        let layer_opts = LfaOptions { threads: 1, ..opts };
        let kernels: Vec<_> = model.layers.iter().map(|l| l.materialize(model.seed)).collect();
        // Non-finite screen — the plan-time gate of the numerical-health
        // layer. A NaN/Inf weight poisons every symbol and every downstream
        // certificate, so it is rejected here, before any plan is built,
        // any signature hashed, or any frequency solved.
        for (l, k) in model.layers.iter().zip(&kernels) {
            let bad = k.non_finite_count();
            if bad > 0 {
                return Err(Error::non_finite_weights(&l.name, bad));
            }
        }
        let plan_keys: Vec<Option<Signature>> = model
            .layers
            .iter()
            .zip(&kernels)
            .map(|(l, k)| {
                cache.map(|_| Signature::plan(k, l.height, l.width, l.stride, &layer_opts))
            })
            .collect();
        let mut plans: Vec<Option<Arc<SpectralPlan>>> = plan_keys
            .iter()
            .map(|key| match (cache, key) {
                (Some(c), Some(k)) => c.plan_lookup(k),
                _ => None,
            })
            .collect();
        // Group the *missing* layers by block shape. Solver and layout are
        // uniform across one plan's options, so the (c_out, c_in, solver,
        // layout) batching key reduces to the block shape here; tap counts
        // may differ within a group and the pool is sized for the largest.
        // (Cached plans arrive with their own — already shared — pools.)
        let missing: Vec<usize> = (0..plans.len()).filter(|&i| plans[i].is_none()).collect();
        let mut keys: Vec<(usize, usize)> = Vec::new();
        let mut fresh_groups: Vec<Vec<usize>> = Vec::new();
        for &i in &missing {
            let (rows, cols, _) = shapes[i];
            match keys.iter().position(|&k| k == (rows, cols)) {
                Some(g) => fresh_groups[g].push(i),
                None => {
                    keys.push((rows, cols));
                    fresh_groups.push(vec![i]);
                }
            }
        }
        for (g, members) in fresh_groups.iter().enumerate() {
            let (rows, cols) = keys[g];
            let ntaps = members.iter().map(|&i| shapes[i].2).max().unwrap_or(1);
            let pool = Arc::new(WorkspacePool::for_block(rows, cols, ntaps));
            for &i in members {
                let l = &model.layers[i];
                let plan = Arc::new(SpectralPlan::with_shared_pool(
                    &kernels[i],
                    l.height,
                    l.width,
                    l.stride,
                    layer_opts,
                    Arc::clone(&pool),
                ));
                let plan = match (cache, &plan_keys[i]) {
                    (Some(c), Some(key)) => c.plan_store(*key, plan),
                    _ => plan,
                };
                plans[i] = Some(plan);
            }
        }
        let plans: Vec<Arc<SpectralPlan>> =
            plans.into_iter().map(|p| p.expect("every layer planned above")).collect();
        // Equal-shape groups = workspace-pool identity: freshly built
        // layers share the pool created above, cache-reused layers share
        // whatever pool they were first built with. Same pool ⇒ same block
        // shape (the plan constructor asserts coverage), so the batched
        // sweep's checkout-per-group-transition stays valid.
        let mut pool_ids: Vec<*const WorkspacePool> = Vec::new();
        let mut groups: Vec<Vec<usize>> = Vec::new();
        let mut group_of = vec![0usize; plans.len()];
        for (i, p) in plans.iter().enumerate() {
            let id = Arc::as_ptr(p.workspace_pool());
            match pool_ids.iter().position(|&q| q == id) {
                Some(g) => {
                    groups[g].push(i);
                    group_of[i] = g;
                }
                None => {
                    pool_ids.push(id);
                    group_of[i] = groups.len();
                    groups.push(vec![i]);
                }
            }
        }
        // Assign buffer offsets in group-major order: one batched sweep per
        // group writes the whole-model buffer front to back.
        let mut offsets = vec![0usize; plans.len()];
        let mut exec_order = Vec::with_capacity(plans.len());
        let mut offset = 0usize;
        for members in &groups {
            for &i in members {
                offsets[i] = offset;
                offset += plans[i].values_len();
                exec_order.push(i);
            }
        }
        let mut layers = Vec::with_capacity(plans.len());
        for (i, plan) in plans.into_iter().enumerate() {
            layers.push(LayerEntry {
                name: model.layers[i].name.clone(),
                plan,
                plan_key: plan_keys[i],
                offset: offsets[i],
                group: group_of[i],
            });
        }
        Ok(ModelPlan {
            name: model.name.clone(),
            layers,
            exec_order,
            groups,
            total_values: offset,
            threads: opts.threads,
        })
    }

    /// Model name (from the config).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Number of planned layers.
    pub fn layer_count(&self) -> usize {
        self.layers.len()
    }

    /// Name of layer `i` (original model order).
    pub fn layer_name(&self, i: usize) -> &str {
        &self.layers[i].name
    }

    /// The planned pipeline of layer `i`.
    pub fn layer_plan(&self, i: usize) -> &SpectralPlan {
        &self.layers[i].plan
    }

    /// The planned pipeline of layer `i`, shared — the `Arc` a
    /// [`SpectralCache`] plan entry would hold.
    pub fn layer_plan_shared(&self, i: usize) -> &Arc<SpectralPlan> {
        &self.layers[i].plan
    }

    /// The plan signature of layer `i` when this model was built against
    /// a [`SpectralCache`] ([`Self::build_cached`]); `None` for plain
    /// builds. Callers derive result signatures from it
    /// ([`Signature::for_request`]) instead of re-hashing the weights.
    pub fn layer_plan_signature(&self, i: usize) -> Option<&Signature> {
        self.layers[i].plan_key.as_ref()
    }

    /// Start of layer `i`'s values in the whole-model buffer.
    pub fn layer_offset(&self, i: usize) -> usize {
        self.layers[i].offset
    }

    /// Number of equal-shape groups (== distinct block shapes).
    pub fn group_count(&self) -> usize {
        self.groups.len()
    }

    /// Member layer indices of group `g`.
    pub fn group_members(&self, g: usize) -> &[usize] {
        &self.groups[g]
    }

    /// Total singular values across all layers — the length of the buffer
    /// [`Self::execute_into`] fills.
    pub fn values_len(&self) -> usize {
        self.total_values
    }

    /// Buffer length of an execution of `request`
    /// ([`Self::values_len`] for `Full`, `Σ freqs·min(k, rank)` for top-k).
    pub fn request_values_len(&self, request: SpectrumRequest) -> usize {
        match request {
            SpectrumRequest::Full => self.total_values,
            SpectrumRequest::TopK(_) => {
                self.layers.iter().map(|l| l.plan.request_values_len(request)).sum()
            }
        }
    }

    /// Per-layer start offsets (indexed in original layer order) into the
    /// flat buffer an execution of `request` fills. The buffer is laid out
    /// in **group-major execution order**; this method is the single source
    /// of truth for that layout — [`Self::spectra_from_flat_request`] and
    /// the coordinator's tile placement both derive from it, so they cannot
    /// drift apart if the execution order ever changes.
    pub fn request_offsets(&self, request: SpectrumRequest) -> Vec<usize> {
        let mut offsets = vec![0usize; self.layers.len()];
        let mut pos = 0usize;
        for &i in &self.exec_order {
            offsets[i] = pos;
            pos += self.layers[i].plan.request_values_len(request);
        }
        offsets
    }

    /// Worker count a whole-model sweep will use (0 in options = auto).
    pub fn effective_threads(&self) -> usize {
        let freqs: usize = self.layers.iter().map(|l| l.plan.freqs()).sum();
        // Tiny models: thread spawn overhead dominates the whole pipeline.
        if freqs < 64 {
            return 1;
        }
        let total_rows: usize = self.layers.iter().map(|l| l.plan.solved_rows()).sum();
        super::resolve_threads(self.threads).min(total_rows.max(1))
    }

    /// Execute every layer into a caller-provided whole-model buffer
    /// (`values_len()` long). Serially this is one group-major batched
    /// sweep — a single workspace checkout per group, zero heap allocation
    /// per frequency. Threaded, the model's frequency rows are partitioned
    /// across one scoped worker fan-out (not one per layer). Returns the
    /// model-merged [`SpectrumHealth`] (a `Copy` value — the serial path
    /// stays allocation-free); callers that need per-layer evidence use
    /// [`Self::execute_request_into_health`].
    pub fn execute_into(&self, out: &mut [f64]) -> SpectrumHealth {
        self.execute_request_into(SpectrumRequest::Full, out).1
    }

    /// Execute `request` for every layer into a caller-provided buffer
    /// (`request_values_len(request)` long, group-major layer order).
    /// Returns total solver iteration steps (0 for `Full`) and the merged
    /// whole-model health. For top-k the serial path warm-starts across
    /// each layer's serpentine sweep (cold per layer — symbols of
    /// different layers are unrelated); threaded, every span is a
    /// contiguous frequency strip of one layer, so warm starts never cross
    /// workers or layers. Layers whose plan folds
    /// ([`crate::lfa::Fold::Auto`], the default) sweep only their
    /// fundamental-domain rows; the conjugate halves are mirrored in at
    /// assembly ([`crate::lfa::spectrum::mirror_fill`]).
    pub fn execute_request_into(
        &self,
        request: SpectrumRequest,
        out: &mut [f64],
    ) -> (u64, SpectrumHealth) {
        let mut merged = SpectrumHealth::default();
        let iters = self.execute_request_observed(request, out, |_, h| merged.merge(&h));
        (iters, merged)
    }

    /// [`Self::execute_request_into`] reporting **per-layer** health into a
    /// caller-provided slice (`layer_count()` long, original layer order) —
    /// the form the spectra-assembly and cache-gating paths consume.
    pub fn execute_request_into_health(
        &self,
        request: SpectrumRequest,
        out: &mut [f64],
        health: &mut [SpectrumHealth],
    ) -> u64 {
        assert_eq!(health.len(), self.layers.len(), "health slice length mismatch");
        self.execute_request_observed(request, out, |i, h| health[i] = h)
    }

    /// Execution core: runs the sweep and reports each layer's aggregated
    /// [`SpectrumHealth`] through `observe(layer_index, health)` exactly
    /// once per layer. The observer is a plain closure so the warmed-up
    /// serial path allocates nothing; the threaded path (which already
    /// allocates spans and spawns workers) aggregates per layer behind a
    /// mutex and drains it into the observer after the scope joins.
    fn execute_request_observed(
        &self,
        request: SpectrumRequest,
        out: &mut [f64],
        mut observe: impl FnMut(usize, SpectrumHealth),
    ) -> u64 {
        let total = self.request_values_len(request);
        assert_eq!(out.len(), total, "output buffer length mismatch");
        let threads = self.effective_threads();
        if threads <= 1 {
            let mut iters = 0u64;
            let mut pos = 0usize;
            for members in &self.groups {
                let mut ws = self.layers[members[0]].plan.checkout();
                for &i in members {
                    let l = &self.layers[i];
                    let len = l.plan.request_values_len(request);
                    let slice = &mut out[pos..pos + len];
                    let vpf = request.values_per_freq(l.plan.rank());
                    let (nc, mc) = (l.plan.coarse_rows(), l.plan.coarse_cols());
                    let srows = l.plan.solved_rows();
                    let solved_len = srows * mc * vpf;
                    // One unified row driver regardless of request shape or
                    // folding; folded layers mirror their bottom half after
                    // the solved strip (solved == whole slice when unfolded).
                    let (it, health) = {
                        let solved = &mut slice[..solved_len];
                        l.plan.execute_request_rows(request, 0, srows, true, &mut ws, solved)
                    };
                    iters += it;
                    if l.plan.folded() {
                        mirror_fill(nc, mc, vpf, slice);
                    }
                    observe(i, health);
                    pos += len;
                }
                self.layers[members[0]].plan.restore(ws);
            }
            return iters;
        }
        // Cut layers into solved-row spans (buffer order), then hand
        // contiguous runs of roughly equal value counts to each worker.
        let offsets = self.request_offsets(request);
        let spans_target = (threads * 4).max(1);
        let total_rows: usize = self.layers.iter().map(|l| l.plan.solved_rows()).sum();
        let rows_per = total_rows.div_ceil(spans_target).max(1);
        let mut spans: Vec<Span> = Vec::new();
        for &i in &self.exec_order {
            let plan = &self.layers[i].plan;
            let nrows = plan.solved_rows();
            let row_vals = plan.coarse_cols() * request.values_per_freq(plan.rank());
            let mut lo = 0usize;
            while lo < nrows {
                let hi = (lo + rows_per).min(nrows);
                spans.push(Span {
                    layer: i,
                    lo,
                    hi,
                    len: (hi - lo) * row_vals,
                    offset: offsets[i] + lo * row_vals,
                });
                lo = hi;
            }
        }
        let solved_total: usize = spans.iter().map(|s| s.len).sum();
        let target = solved_total.div_ceil(threads).max(1);
        let iters_total = AtomicU64::new(0);
        let iters_ref = &iters_total;
        let layer_health = Mutex::new(vec![SpectrumHealth::default(); self.layers.len()]);
        let health_ref = &layer_health;
        std::thread::scope(|scope| {
            let mut rest: &mut [f64] = out;
            let mut pos = 0usize;
            let mut s0 = 0usize;
            while s0 < spans.len() {
                let mut s1 = s0;
                let mut acc = 0usize;
                while s1 < spans.len() && acc < target {
                    acc += spans[s1].len;
                    s1 += 1;
                }
                // Per-span output slices: spans are disjoint and ascending
                // in the buffer, but folded layers leave gaps between them
                // (their mirrored bottom halves, filled after the sweep).
                let mut bufs: Vec<&mut [f64]> = Vec::with_capacity(s1 - s0);
                for s in &spans[s0..s1] {
                    let (_gap, tail) = std::mem::take(&mut rest).split_at_mut(s.offset - pos);
                    let (head, tail2) = tail.split_at_mut(s.len);
                    rest = tail2;
                    pos = s.offset + s.len;
                    bufs.push(head);
                }
                let chunk = &spans[s0..s1];
                scope.spawn(move || {
                    let it = self.execute_spans(request, chunk, bufs, health_ref);
                    iters_ref.fetch_add(it, Ordering::Relaxed);
                });
                s0 = s1;
            }
        });
        for (i, h) in layer_health.into_inner().unwrap().into_iter().enumerate() {
            observe(i, h);
        }
        // Mirror the conjugate halves of folded layers.
        for (i, l) in self.layers.iter().enumerate() {
            if l.plan.folded() {
                let len = l.plan.request_values_len(request);
                let vpf = request.values_per_freq(l.plan.rank());
                mirror_fill(
                    l.plan.coarse_rows(),
                    l.plan.coarse_cols(),
                    vpf,
                    &mut out[offsets[i]..offsets[i] + len],
                );
            }
        }
        iters_total.into_inner()
    }

    /// Worker body: execute a run of spans (span `i` into `bufs[i]`),
    /// checking one workspace out per group transition (spans arrive
    /// group-major, so a worker crossing layers inside one group keeps its
    /// scratch; top-k warm starts stay within one span's strip). Each
    /// span's health merges into its layer's slot of `layer_health`.
    fn execute_spans(
        &self,
        request: SpectrumRequest,
        spans: &[Span],
        bufs: Vec<&mut [f64]>,
        layer_health: &Mutex<Vec<SpectrumHealth>>,
    ) -> u64 {
        let mut cur_group = usize::MAX;
        let mut ws: Option<Workspace> = None;
        let mut iters = 0u64;
        for (s, buf) in spans.iter().zip(bufs) {
            let l = &self.layers[s.layer];
            if l.group != cur_group {
                if let Some(w) = ws.take() {
                    self.group_pool(cur_group).restore(w);
                }
                ws = Some(l.plan.checkout());
                cur_group = l.group;
            }
            let w = ws.as_mut().expect("workspace checked out above");
            let (it, health) = l.plan.execute_request_rows(request, s.lo, s.hi, true, w, buf);
            iters += it;
            layer_health.lock().unwrap()[s.layer].merge(&health);
        }
        if let Some(w) = ws.take() {
            self.group_pool(cur_group).restore(w);
        }
        iters
    }

    fn group_pool(&self, g: usize) -> &Arc<WorkspacePool> {
        self.layers[self.groups[g][0]].plan.workspace_pool()
    }

    /// Execute the whole model and package per-layer spectra (each
    /// carrying its sweep's [`SpectrumHealth`]).
    pub fn execute(&self) -> ModelSpectra {
        let request = SpectrumRequest::Full;
        let mut values = vec![0.0f64; self.total_values];
        let mut health = vec![SpectrumHealth::default(); self.layers.len()];
        self.execute_request_into_health(request, &mut values, &mut health);
        self.spectra_from_flat_health(request, &values, &health)
    }

    /// Execute every layer back-to-back through an explicit backend
    /// (serial, threaded, or — feature `pjrt` — an AOT artifact sweep).
    /// Per-layer health is whatever the backend reports (empty for
    /// backends that carry no certificates across their boundary).
    pub fn execute_with(&self, backend: &dyn SpectralBackend) -> Result<ModelSpectra> {
        let mut values = vec![0.0f64; self.total_values];
        let mut health = vec![SpectrumHealth::default(); self.layers.len()];
        for &i in &self.exec_order {
            let l = &self.layers[i];
            health[i] = backend
                .execute_into(&l.plan, &mut values[l.offset..l.offset + l.plan.values_len()])?;
        }
        Ok(self.spectra_from_flat_health(SpectrumRequest::Full, &values, &health))
    }

    /// Split a flat whole-model buffer (as filled by [`Self::execute_into`])
    /// into per-layer spectra, original model order.
    pub fn spectra_from_flat(&self, values: &[f64]) -> ModelSpectra {
        self.spectra_from_flat_request(SpectrumRequest::Full, values)
    }

    /// [`Self::spectra_from_flat`] for any request: slice a buffer filled
    /// by [`Self::execute_request_into`] into per-layer (possibly partial)
    /// spectra, original model order. Each layer gets the clean-bill
    /// health of [`SpectralPlan::spectrum_from_values`] — callers holding
    /// real per-layer evidence use [`Self::spectra_from_flat_health`].
    pub fn spectra_from_flat_request(
        &self,
        request: SpectrumRequest,
        values: &[f64],
    ) -> ModelSpectra {
        assert_eq!(
            values.len(),
            self.request_values_len(request),
            "flat buffer length mismatch"
        );
        let offsets = self.request_offsets(request);
        let layers = self
            .layers
            .iter()
            .enumerate()
            .map(|(i, l)| {
                let p = &l.plan;
                let len = p.request_values_len(request);
                let slice = values[offsets[i]..offsets[i] + len].to_vec();
                LayerSpectrum {
                    name: l.name.clone(),
                    spectrum: Arc::new(p.spectrum_from_values(request, slice)),
                }
            })
            .collect();
        ModelSpectra { model: self.name.clone(), layers }
    }

    /// [`Self::spectra_from_flat_request`] with per-layer health evidence
    /// (`layer_count()` long, original order) attached to each spectrum —
    /// the assembly used by every live (non-cache-hit) execution.
    pub fn spectra_from_flat_health(
        &self,
        request: SpectrumRequest,
        values: &[f64],
        health: &[SpectrumHealth],
    ) -> ModelSpectra {
        assert_eq!(
            values.len(),
            self.request_values_len(request),
            "flat buffer length mismatch"
        );
        assert_eq!(health.len(), self.layers.len(), "health slice length mismatch");
        let offsets = self.request_offsets(request);
        let layers = self
            .layers
            .iter()
            .enumerate()
            .map(|(i, l)| {
                let p = &l.plan;
                let len = p.request_values_len(request);
                let slice = values[offsets[i]..offsets[i] + len].to_vec();
                LayerSpectrum {
                    name: l.name.clone(),
                    spectrum: Arc::new(p.spectrum_from_values_health(request, slice, health[i])),
                }
            })
            .collect();
        ModelSpectra { model: self.name.clone(), layers }
    }

    /// Top-`k` singular values per frequency for **every** layer, one
    /// batched warm-started top-k sweep — the whole-model analogue of
    /// [`SpectralPlan::execute_topk`]. This is the execution mode behind
    /// fast Lipschitz reporting and clip screening: when only the extreme
    /// values are consumed, it replaces the `O(c³)` per-frequency Jacobi
    /// solve with a few `O(c²k)` iterations.
    pub fn top_k_all(&self, k: usize) -> ModelTopK {
        let request = SpectrumRequest::TopK(k);
        let mut values = vec![0.0f64; self.request_values_len(request)];
        let mut health = vec![SpectrumHealth::default(); self.layers.len()];
        let iterations = self.execute_request_into_health(request, &mut values, &mut health);
        ModelTopK {
            spectra: self.spectra_from_flat_health(request, &values, &health),
            k,
            iterations,
        }
    }

    /// Execute `request` for every layer **through a result cache**: a
    /// layer whose signature (weight bits + geometry + options + request)
    /// matches a cached spectrum is served from it — zero frequencies
    /// re-solved — and only the missing layers execute. The repeat-audit
    /// shape: the first sweep populates the cache (one batched sweep,
    /// identical to [`Self::execute_request_into`]); every following sweep
    /// of an unchanged model is pure lookup. After a weight mutation
    /// (training-loop clipping), only the mutated layers recompute.
    pub fn execute_request_cached(
        &self,
        request: SpectrumRequest,
        cache: &SpectralCache,
    ) -> CachedExecution {
        // Result keys derive from the stored plan signatures when this
        // model was built cached — one weight-tensor hash per layer per
        // build, not one per sweep.
        let keys: Vec<Signature> = self
            .layers
            .iter()
            .map(|l| match &l.plan_key {
                Some(ps) => ps.for_request(request),
                None => l.plan.result_signature(request),
            })
            .collect();
        let mut found: Vec<Option<Arc<Spectrum>>> = keys.iter().map(|k| cache.get(k)).collect();
        let miss_count = found.iter().filter(|f| f.is_none()).count();
        let cache_hits = self.layers.len() - miss_count;
        if miss_count == self.layers.len() {
            // All cold: one batched group-major sweep, exactly the
            // uncached path, then every layer's slice enters the cache —
            // and the assembled spectra ship as-is, no rebuild. A layer
            // still degraded after the escalation ladder ships flagged but
            // is refused by the cache ([`SpectralCache::insert`] gates on
            // health), so a poisoned result can never be replayed.
            let mut values = vec![0.0f64; self.request_values_len(request)];
            let mut health = vec![SpectrumHealth::default(); self.layers.len()];
            let iterations = self.execute_request_into_health(request, &mut values, &mut health);
            let spectra = self.spectra_from_flat_health(request, &values, &health);
            let mut evictions = 0u64;
            let mut freqs_solved = 0usize;
            for (i, layer) in spectra.layers.iter().enumerate() {
                evictions += cache.insert(keys[i], Arc::clone(&layer.spectrum));
                freqs_solved += self.layers[i].plan.solved_freqs();
            }
            return CachedExecution { spectra, iterations, cache_hits: 0, freqs_solved, evictions };
        }
        // Mixed (or all-hit): recompute only the missing layers (each
        // with the model's worker budget — misses are few in repeat
        // traffic).
        let mut iterations = 0u64;
        let mut evictions = 0u64;
        let mut freqs_solved = 0usize;
        for (i, l) in self.layers.iter().enumerate() {
            if found[i].is_some() {
                continue;
            }
            let p = &l.plan;
            let mut values = vec![0.0f64; p.request_values_len(request)];
            let (it, health) = p.execute_request_into(
                request,
                SweepOptions::with_threads(self.threads),
                &mut values,
            );
            iterations += it;
            let sp = Arc::new(p.spectrum_from_values_health(request, values, health));
            evictions += cache.insert(keys[i], Arc::clone(&sp));
            freqs_solved += p.solved_freqs();
            found[i] = Some(sp);
        }
        let layers = self
            .layers
            .iter()
            .zip(found)
            .map(|(l, sp)| LayerSpectrum {
                name: l.name.clone(),
                spectrum: sp.expect("every layer either hit or was recomputed"),
            })
            .collect();
        CachedExecution {
            spectra: ModelSpectra { model: self.name.clone(), layers },
            iterations,
            cache_hits,
            freqs_solved,
            evictions,
        }
    }

    /// Full-spectrum [`Self::execute`] through a result cache — see
    /// [`Self::execute_request_cached`].
    pub fn execute_cached(&self, cache: &SpectralCache) -> CachedExecution {
        self.execute_request_cached(SpectrumRequest::Full, cache)
    }

    /// [`Self::top_k_all`] through a result cache: partial spectra are
    /// cached under their `TopK(k)` signature, so repeated Lipschitz
    /// screens and clip sweeps of unchanged layers cost a lookup.
    pub fn top_k_all_cached(&self, k: usize, cache: &SpectralCache) -> CachedExecution {
        self.execute_request_cached(SpectrumRequest::TopK(k), cache)
    }

    /// Network Lipschitz composition bound (product of per-layer spectral
    /// norms — Szegedy et al. 2014) via a **top-1** sweep: the same number
    /// [`ModelSpectra::lipschitz_upper_bound`] reports after a full
    /// execution, at a fraction of the cost. Returns the bound and the
    /// solver iteration steps spent.
    pub fn lipschitz_bound_topk(&self) -> (f64, u64) {
        let r = self.top_k_all(1);
        (r.spectra.lipschitz_upper_bound(), r.iterations)
    }

    /// Full per-frequency SVD of every layer (original model order).
    ///
    /// ```
    /// use conv_svd_lfa::engine::ModelPlan;
    /// use conv_svd_lfa::lfa::LfaOptions;
    /// use conv_svd_lfa::model::ModelConfig;
    ///
    /// let model = ModelConfig::parse(
    ///     "name = \"tiny\"\nseed = 3\n\
    ///      [[layer]]\nname = \"c1\"\nc_in = 2\nc_out = 3\nheight = 4\nwidth = 4\n",
    /// )
    /// .unwrap();
    /// let plan = ModelPlan::build(&model, LfaOptions::default()).unwrap();
    /// let svds = plan.full_svd_all();
    /// assert_eq!(svds.len(), 1);
    /// // Per-frequency factors reconstruct each symbol: U_k Σ_k V_kᴴ.
    /// let sym = svds[0].symbol(0);
    /// assert_eq!((sym.rows, sym.cols), (3, 2));
    /// ```
    pub fn full_svd_all(&self) -> Vec<FullSvd> {
        self.layers.iter().map(|l| l.plan.full_svd()).collect()
    }

    /// Clip every layer's spectrum at `cap` against the held plans — the
    /// training-loop shape: plan once at startup, clip every step without
    /// re-planning. Only defined for stride-1 layers (the least-squares
    /// kernel projection needs the dense symbol grid).
    ///
    /// A cheap **top-1 screening sweep** runs first: layers whose spectral
    /// norm is already ≤ `cap` skip the full per-frequency SVD and the
    /// reconstruction entirely (their kernel is returned unchanged) — in a
    /// training loop most layers are below the cap most steps, so this is
    /// where the top-k engine pays off.
    pub fn clip_all(&self, cap: f64) -> Result<Vec<ClipResult>> {
        self.clip_all_inner(cap, None)
    }

    /// [`Self::clip_all`] with the **top-1 screening sweep served through
    /// a result cache**: in a training loop, layers whose weights haven't
    /// changed since the last step screen from cache (zero frequencies
    /// re-solved) and only the mutated layers run the Krylov sweep.
    pub fn clip_all_cached(&self, cap: f64, cache: &SpectralCache) -> Result<Vec<ClipResult>> {
        self.clip_all_inner(cap, Some(cache))
    }

    fn clip_all_inner(&self, cap: f64, cache: Option<&SpectralCache>) -> Result<Vec<ClipResult>> {
        for l in &self.layers {
            if l.plan.stride() != 1 {
                bail!(
                    "clip_all: layer {:?} has stride {} — kernel projection is only \
                     defined for dense (stride-1) layers",
                    l.name,
                    l.plan.stride()
                );
            }
            if !l.plan.kernel().is_dense() {
                bail!(
                    "clip_all: layer {:?} is structured (groups {}, dilation {}, \
                     transposed {}) — the least-squares kernel projection is only \
                     defined for dense forward layers",
                    l.name,
                    l.plan.kernel().groups,
                    l.plan.kernel().dilation,
                    l.plan.kernel().transposed
                );
            }
        }
        let screen = match cache {
            Some(c) => self.top_k_all_cached(1, c).spectra,
            None => self.top_k_all(1).spectra,
        };
        Ok(self
            .layers
            .iter()
            .zip(&screen.layers)
            .map(|(l, s)| {
                let sigma_before = s.spectrum.sigma_max();
                if sigma_before <= cap {
                    unclipped_result(&l.plan, sigma_before)
                } else {
                    clip_with_plan(&l.plan, cap)
                }
            })
            .collect())
    }

    /// Rank-`r` truncation of every layer (Eckart–Young optimal per
    /// frequency), original model order.
    pub fn lowrank_all(&self, rank: usize) -> Vec<LowRankConv> {
        self.layers.iter().map(|l| compress_from_svd(&l.plan.full_svd(), rank)).collect()
    }

    /// Streaming spectral-density sweep of every layer, original model
    /// order: each layer runs the two-pass density pipeline
    /// ([`SpectralPlan::density_with`] — exact top-1 extremes, then
    /// histogram accumulation over the (optionally sub-sampled) dual
    /// grid) with the model's worker budget. Nothing is assembled: the
    /// whole-model footprint is `layers × bins` counters instead of
    /// `layers × freqs × rank` values.
    pub fn density_all(&self, req: DensityRequest) -> Vec<LayerDensity> {
        self.layers
            .iter()
            .map(|l| LayerDensity {
                name: l.name.clone(),
                density: Arc::new(
                    l.plan.density_with(req, SweepOptions::with_threads(self.threads)),
                ),
                cached: false,
            })
            .collect()
    }

    /// [`Self::density_all`] through a result cache: densities are keyed
    /// like spectra (weight bits + geometry + options + density request,
    /// [`Signature::for_density`]) and share the cache's byte budget, so
    /// a repeat density audit of an unchanged model solves zero
    /// frequencies. The health gate is unchanged: a layer still degraded
    /// after the escalation ladder ships flagged but is refused by the
    /// cache, so it recomputes (and re-flags) on every sweep instead of
    /// being replayed as trustworthy.
    pub fn density_all_cached(&self, req: DensityRequest, cache: &SpectralCache) -> Vec<LayerDensity> {
        self.layers
            .iter()
            .map(|l| {
                let key = match &l.plan_key {
                    Some(ps) => ps.for_density(req),
                    None => l.plan.density_signature(req),
                };
                if let Some(d) = cache.get_density(&key) {
                    return LayerDensity { name: l.name.clone(), density: d, cached: true };
                }
                let d = Arc::new(
                    l.plan.density_with(req, SweepOptions::with_threads(self.threads)),
                );
                cache.insert_density(key, Arc::clone(&d));
                LayerDensity { name: l.name.clone(), density: d, cached: false }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;

    const MIXED: &str = r#"
name = "mixed"
seed = 11

[[layer]]
name   = "a1"
c_in   = 3
c_out  = 4
height = 8
width  = 8

[[layer]]
name   = "b"
c_in   = 2
c_out  = 3
height = 6
width  = 6

[[layer]]
name   = "a2"
c_in   = 3
c_out  = 4
height = 4
width  = 8
"#;

    #[test]
    fn groups_equal_shapes_and_preserves_order() {
        let model = ModelConfig::parse(MIXED).unwrap();
        let mp = ModelPlan::build(&model, LfaOptions { threads: 1, ..Default::default() })
            .unwrap();
        assert_eq!(mp.layer_count(), 3);
        assert_eq!(mp.group_count(), 2, "a1 and a2 share a 4x3 group");
        assert_eq!(mp.group_members(0), &[0, 2]);
        assert_eq!(mp.group_members(1), &[1]);
        assert_eq!(
            mp.values_len(),
            model.layers.iter().map(|l| l.num_values()).sum::<usize>()
        );
        let spectra = mp.execute();
        // Spectra come back in original model order regardless of grouping.
        assert_eq!(spectra.layers[0].name, "a1");
        assert_eq!(spectra.layers[1].name, "b");
        assert_eq!(spectra.layers[2].name, "a2");
        assert_eq!(spectra.num_values(), mp.values_len());
        assert!(spectra.sigma_max() > 0.0);
        assert!(spectra.lipschitz_upper_bound() > 0.0);
        assert!(spectra.layer("b").is_some());
        assert!(spectra.layer("nope").is_none());
    }

    #[test]
    fn top_k_all_matches_full_extremes() {
        let model = ModelConfig::parse(MIXED).unwrap();
        let mp = ModelPlan::build(&model, LfaOptions { threads: 1, ..Default::default() })
            .unwrap();
        let full = mp.execute();
        let top = mp.top_k_all(2);
        assert_eq!(top.k, 2);
        assert!(top.iterations > 0);
        let scale = full.sigma_max();
        for (fl, tl) in full.layers.iter().zip(&top.spectra.layers) {
            assert_eq!(fl.name, tl.name);
            assert_eq!(tl.spectrum.rank_per_freq(), 2);
            let freqs = tl.spectrum.n * tl.spectrum.m;
            for f in 0..freqs {
                for j in 0..2 {
                    assert!(
                        (fl.spectrum.at(f)[j] - tl.spectrum.at(f)[j]).abs() <= 1e-8 * scale,
                        "{} f={f} j={j}",
                        fl.name
                    );
                }
            }
        }
        // The Lipschitz bound off the partial spectra equals the full one.
        let (fast, iters) = mp.lipschitz_bound_topk();
        assert!(iters > 0);
        assert!(
            (fast - full.lipschitz_upper_bound()).abs() <= 1e-7 * full.lipschitz_upper_bound()
        );
    }

    #[test]
    fn density_all_cached_serves_repeat_sweeps_from_cache() {
        let model = ModelConfig::parse(MIXED).unwrap();
        let mp = ModelPlan::build(&model, LfaOptions { threads: 1, ..Default::default() })
            .unwrap();
        let req = DensityRequest { bins: 32, sample: 1 };
        let uncached = mp.density_all(req);
        assert_eq!(uncached.len(), 3);
        let full = mp.execute();
        for (ld, fl) in uncached.iter().zip(&full.layers) {
            assert_eq!(ld.name, fl.name);
            assert!(!ld.cached);
            // Census densities (sample=1) see every frequency: exact
            // extremes and a singular-value count matching the spectrum.
            assert_eq!(ld.density.covered_freqs, ld.density.total_freqs);
            assert_eq!(ld.density.count(), fl.spectrum.values.len() as u64);
            // σ_max comes from the pass-1 Krylov top-1 sweep; compare at
            // the solver tolerance, as the top-k tests do.
            assert!(
                (ld.density.sigma_max - fl.spectrum.sigma_max()).abs()
                    <= 1e-8 * fl.spectrum.sigma_max()
            );
        }
        // Cached: first sweep populates, second sweep is pure lookup
        // sharing the same Arc'd histograms.
        let cache = SpectralCache::new();
        let first = mp.density_all_cached(req, &cache);
        assert!(first.iter().all(|l| !l.cached));
        assert_eq!(cache.stats().density_entries, 3);
        let second = mp.density_all_cached(req, &cache);
        assert!(second.iter().all(|l| l.cached));
        for (a, b) in first.iter().zip(&second) {
            assert!(Arc::ptr_eq(&a.density, &b.density), "{}", a.name);
        }
        // A different density request is a different key: it misses.
        let third = mp.density_all_cached(DensityRequest { bins: 16, sample: 2 }, &cache);
        assert!(third.iter().all(|l| !l.cached));
        // A cached-build model derives density keys from its stored plan
        // signatures and hits the same entries.
        let mp2 = ModelPlan::build_cached(
            &model,
            LfaOptions { threads: 1, ..Default::default() },
            &cache,
        )
        .unwrap();
        let derived = mp2.density_all_cached(req, &cache);
        assert!(derived.iter().all(|l| l.cached), "plan-key derived keys must hit");
    }

    #[test]
    fn clip_all_screening_skips_layers_below_cap() {
        let model = ModelConfig::parse(MIXED).unwrap();
        let mp = ModelPlan::build(&model, LfaOptions { threads: 1, ..Default::default() })
            .unwrap();
        let full = mp.execute();
        // Cap above every σ: nothing clips, kernels come back bit-identical.
        let cap = full.sigma_max() * 2.0;
        let results = mp.clip_all(cap).unwrap();
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.clipped_count, 0, "layer {i}");
            let k = mp.layer_plan(i).kernel();
            assert_eq!(r.projected_kernel.data, k.data, "layer {i}: kernel untouched");
        }
        // Cap below σ_max: the over-cap layers still clip exactly.
        let cap = full.sigma_max() * 0.5;
        let results = mp.clip_all(cap).unwrap();
        let clipped: usize = results.iter().map(|r| r.clipped_count).sum();
        assert!(clipped > 0, "something must clip at half σ_max");
        for (i, r) in results.iter().enumerate() {
            if full.layers[i].spectrum.sigma_max() > cap {
                let direct = crate::spectral::clip::clip_with_plan(mp.layer_plan(i), cap);
                assert_eq!(r.clipped_count, direct.clipped_count, "layer {i}");
                for (a, b) in r.projected_kernel.data.iter().zip(&direct.projected_kernel.data)
                {
                    assert!((a - b).abs() < 1e-12, "layer {i}");
                }
            }
        }
    }

    #[test]
    fn empty_model_is_rejected() {
        let model = ModelConfig {
            name: "empty".into(),
            seed: 0,
            layers: Vec::new(),
        };
        assert!(ModelPlan::build(&model, LfaOptions::default()).is_err());
    }

    #[test]
    fn non_finite_weights_rejected_at_build() {
        use crate::error::ErrorKind;
        let model = ModelConfig::parse(
            "name = \"bad\"\nseed = 1\n\
             [[layer]]\nname = \"ok\"\nc_in = 2\nc_out = 2\nheight = 4\nwidth = 4\n\
             [[layer]]\nname = \"poisoned\"\nc_in = 2\nc_out = 2\nheight = 4\nwidth = 4\n\
             init = \"const:nan\"\n",
        )
        .unwrap();
        let err = ModelPlan::build(&model, LfaOptions::default()).unwrap_err();
        match err.kind() {
            ErrorKind::NonFiniteWeights { layer, count } => {
                assert_eq!(layer, "poisoned");
                assert_eq!(*count, 2 * 2 * 3 * 3);
            }
            other => panic!("expected NonFiniteWeights, got {other:?}"),
        }
    }

    #[test]
    fn healthy_model_reports_clean_health() {
        let model = ModelConfig::parse(MIXED).unwrap();
        let mp = ModelPlan::build(&model, LfaOptions { threads: 1, ..Default::default() })
            .unwrap();
        let spectra = mp.execute();
        assert!(!spectra.is_degraded());
        assert!(spectra.degraded_layers().is_empty());
        let merged = spectra.health();
        let solved: u64 = (0..mp.layer_count())
            .map(|i| mp.layer_plan(i).solved_freqs() as u64)
            .sum();
        assert_eq!(merged.converged_freqs + merged.retried_freqs, solved);
        assert_eq!(merged.degraded_freqs, 0);
        // The raw-buffer entry point reports the same merged evidence.
        let mut out = vec![0.0f64; mp.values_len()];
        let h = mp.execute_into(&mut out);
        assert_eq!(h.degraded_freqs, 0);
        assert_eq!(h.converged_freqs + h.retried_freqs, solved);
    }
}
