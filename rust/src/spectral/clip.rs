//! Singular-value clipping — the spectral-norm regularization application
//! (§I / §II-c: Yoshida–Miyato, Sedghi et al., Cisse et al.).
//!
//! Clip every per-frequency singular value at `cap`, rebuild the symbols
//! `U_k min(Σ_k, cap) V_kᴴ`, and optionally project back to a `kh×kw`
//! kernel (the exact clipped operator generally has full spatial support;
//! the projection is the least-squares-nearest local kernel, exactly the
//! procedure of Sedghi et al. §4).

use crate::conv::ConvKernel;
use crate::engine::SpectralPlan;
use crate::lfa::svd::map_singular_values;
use crate::lfa::{self, LfaOptions, SymbolGrid};

/// Result of a clipping pass.
pub struct ClipResult {
    /// Symbol grid of the exactly-clipped operator.
    pub grid: SymbolGrid,
    /// Least-squares projection back onto the original kernel support.
    pub projected_kernel: ConvKernel,
    /// σ_max before clipping.
    pub sigma_before: f64,
    /// Number of singular values that hit the cap.
    pub clipped_count: usize,
}

/// Clip the spectrum of `kernel` (on an `n×m` periodic grid) at `cap`.
///
/// Builds a throwaway [`SpectralPlan`]. Training loops that clip the same
/// layer every step should hold a plan and call [`clip_with_plan`] —
/// spectral clipping is exactly the repeated-spectrum workload the
/// plan-once/execute-many engine exists for.
pub fn clip_spectral_norm(
    kernel: &ConvKernel,
    n: usize,
    m: usize,
    cap: f64,
    opts: LfaOptions,
) -> ClipResult {
    clip_with_plan(&SpectralPlan::new(kernel, n, m, opts), cap)
}

/// Clip against an existing plan (the plan's kernel is the layer clipped).
pub fn clip_with_plan(plan: &SpectralPlan, cap: f64) -> ClipResult {
    let svd = plan.full_svd();
    let kernel = plan.kernel();
    let sigma_before = svd.sigma.sigma_max();
    let clipped_count = svd.sigma.values.iter().filter(|&&s| s > cap).count();
    let grid = map_singular_values(&svd, |s| s.min(cap));
    let projected_kernel =
        lfa::taps_from_symbols(&grid, kernel.kh, kernel.kw, kernel.anchor);
    ClipResult { grid, projected_kernel, sigma_before, clipped_count }
}

/// Cheap clip screening: the layer's spectral norm via a **top-1**
/// warm-started top-k sweep, and whether it exceeds `cap`. Costs
/// `O(n·m·c²)` per verification iteration instead of the full `O(n·m·c³)`
/// decomposition — the right first step for a training loop that clips
/// only when needed. Returns `(σ_max, σ_max > cap, iterations)`.
///
/// The screen consumes the sweep's convergence certificate: if any
/// frequency stayed degraded after the escalation ladder, the computed
/// σ_max cannot witness "safely under the cap", so the layer is
/// conservatively reported as needing clipping regardless of the value —
/// a regularization loop must never *skip* a clip on uncertified evidence.
pub fn needs_clipping(plan: &SpectralPlan, cap: f64) -> (f64, bool, u64) {
    let top = plan.execute_topk(1);
    let sigma = top.spectrum.sigma_max();
    let over = sigma > cap || top.spectrum.health.is_degraded();
    (sigma, over, top.iterations)
}

/// The [`ClipResult`] of a layer established (e.g. by [`needs_clipping`]
/// or a whole-model top-1 screen) to already satisfy `σ_max ≤ cap`: the
/// symbol grid is materialized directly — no per-frequency SVD, no
/// reconstruction — and the kernel is returned unchanged.
pub fn unclipped_result(plan: &SpectralPlan, sigma_before: f64) -> ClipResult {
    ClipResult {
        grid: plan.compute_symbols(),
        projected_kernel: plan.kernel().clone(),
        sigma_before,
        clipped_count: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lfa::svd::svd_full_from_grid;
    use crate::numeric::Pcg64;

    #[test]
    fn clipped_grid_has_capped_norm() {
        let mut rng = Pcg64::seeded(150);
        let k = ConvKernel::random_he(4, 4, 3, 3, &mut rng);
        let (n, m) = (8, 8);
        let cap = 0.8;
        let res = clip_spectral_norm(&k, n, m, cap, Default::default());
        assert!(res.sigma_before > cap, "test needs something to clip");
        assert!(res.clipped_count > 0);
        // Re-decompose the clipped grid: σ_max must be ≤ cap (+ε).
        let svd = svd_full_from_grid(&res.grid);
        assert!(svd.sigma.sigma_max() <= cap + 1e-9, "{}", svd.sigma.sigma_max());
    }

    #[test]
    fn values_below_cap_untouched() {
        let mut rng = Pcg64::seeded(151);
        let k = ConvKernel::random_he(3, 3, 3, 3, &mut rng);
        let (n, m) = (6, 6);
        let before = lfa::singular_values(&k, n, m, Default::default());
        let cap = before.sigma_max() * 2.0; // nothing exceeds
        let res = clip_spectral_norm(&k, n, m, cap, Default::default());
        assert_eq!(res.clipped_count, 0);
        // Grid unchanged → projected kernel == original.
        for (a, b) in k.data.iter().zip(&res.projected_kernel.data) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn screening_agrees_with_full_norm() {
        let mut rng = Pcg64::seeded(153);
        let k = ConvKernel::random_he(4, 4, 3, 3, &mut rng);
        let plan = SpectralPlan::new(&k, 8, 8, Default::default());
        let exact = plan.execute().sigma_max();
        let (sigma, over, iters) = needs_clipping(&plan, exact * 0.9);
        assert!((sigma - exact).abs() <= 1e-8 * exact, "{sigma} vs {exact}");
        assert!(over && iters > 0);
        let (_, under, _) = needs_clipping(&plan, exact * 1.1);
        assert!(!under);
        // A screened-out layer produces a no-op result.
        let res = unclipped_result(&plan, sigma);
        assert_eq!(res.clipped_count, 0);
        assert_eq!(res.projected_kernel.data, k.data);
        let direct = crate::lfa::compute_symbols(
            &k,
            8,
            8,
            crate::lfa::BlockLayout::BlockContiguous,
        );
        assert!(res.grid.max_abs_diff(&direct) < 1e-12);
    }

    #[test]
    fn projected_kernel_reduces_norm() {
        let mut rng = Pcg64::seeded(152);
        let k = ConvKernel::random_he(4, 4, 3, 3, &mut rng);
        let (n, m) = (8, 8);
        let before = lfa::singular_values(&k, n, m, Default::default()).sigma_max();
        let cap = before * 0.5;
        let res = clip_spectral_norm(&k, n, m, cap, Default::default());
        let after =
            lfa::singular_values(&res.projected_kernel, n, m, Default::default()).sigma_max();
        // Projection re-introduces some energy above the cap, but must land
        // well below the original norm.
        assert!(after < before, "projected σ {after} vs original {before}");
        assert!(after < cap * 1.5, "projected σ {after} vs cap {cap}");
    }
}
