//! Spectral-norm estimators compared: exact LFA vs the §II-b baselines
//! (Yoshida–Miyato reshape, power iteration on the true operator, the Gouk
//! Hölder bound). Used by the audit example and the ablation bench.
//!
//! The fast path for production Lipschitz certification is
//! [`sigma_max_topk`]: the exact LFA norm computed by the engine's
//! warm-started top-1 sweep instead of the full per-frequency
//! decomposition — same number, `O(n·m·c²)` per verification iteration.

use crate::conv::{Boundary, ConvKernel, ConvOp};
use crate::engine::SpectralPlan;
use crate::lfa::{self, LfaOptions};
use crate::linalg::{gk_svd, power};
use crate::numeric::Pcg64;

/// All spectral-norm estimates for one layer.
#[derive(Clone, Debug)]
pub struct SpectralNormReport {
    /// Exact σ_max (periodic) from the LFA spectrum.
    pub exact_lfa: f64,
    /// Power iteration on the true (periodic) operator.
    pub power_iteration: f64,
    /// σ_max of the Yoshida–Miyato reshaped `c_out×(c_in·k²)` matrix. This
    /// *approximation* can sit on either side of the exact norm; the
    /// provable upper bound is `√(kh·kw) · σ_reshape` (Tsuzuku et al. 2018),
    /// reported in [`Self::ym_upper_bound`].
    pub ym_reshape: f64,
    /// `√(kh·kw) · ym_reshape` — the certified upper bound.
    pub ym_upper_bound: f64,
    /// Gouk Hölder bound `√(‖A‖₁‖A‖_∞)` — computed from tap sums
    /// (periodic rows/columns all share the same absolute sums).
    pub holder_bound: f64,
    /// Condition number of the operator (periodic).
    pub condition: f64,
    /// Convergence certificate of the power-iteration estimate
    /// ([`crate::linalg::power::PowerResult::converged`]): `false` means
    /// the iteration hit its step cap before the Rayleigh quotient
    /// settled, so [`Self::power_iteration`] is a *lower bound* on the
    /// norm, not an estimate of it — comparisons against `exact_lfa`
    /// should be skipped rather than trusted.
    pub power_converged: bool,
}

/// Compute every estimator for a kernel on an `n×m` grid.
pub fn spectral_report(kernel: &ConvKernel, n: usize, m: usize, opts: LfaOptions) -> SpectralNormReport {
    let spec = lfa::singular_values(kernel, n, m, opts);
    let mut rng = Pcg64::seeded(0xB0A71);
    let op = ConvOp::new(kernel, n, m, Boundary::Periodic);
    let pi = power::spectral_norm(&op, 1000, 1e-10, &mut rng);
    let ym = gk_svd::singular_values(&kernel.reshaped_matrix())[0];
    SpectralNormReport {
        exact_lfa: spec.sigma_max(),
        power_iteration: pi.sigma_max,
        ym_reshape: ym,
        ym_upper_bound: ((kernel.kh * kernel.kw) as f64).sqrt() * ym,
        holder_bound: holder_from_taps(kernel),
        condition: spec.condition_number(),
        power_converged: pi.converged,
    }
}

/// Exact spectral norm (= the layer's Lipschitz constant under periodic
/// BC) via the engine's **top-1 partial-spectrum sweep**: per frequency,
/// warm-started Krylov iteration finds only σ_max instead of the whole
/// decomposition. Unlike [`power::spectral_norm`] on the spatial operator
/// (one global power iteration, approximate), this resolves every
/// frequency exactly and takes the true maximum. Returns
/// `(σ_max, solver iteration steps spent)`.
pub fn sigma_max_topk(
    kernel: &ConvKernel,
    n: usize,
    m: usize,
    opts: LfaOptions,
) -> (f64, u64) {
    let plan = SpectralPlan::new(kernel, n, m, opts);
    let top = plan.execute_topk(1);
    (top.spectrum.sigma_max(), top.iterations)
}

/// Gouk bound computed directly from the weight tensor: under periodic BC
/// every unrolled row for output channel `o` has absolute sum
/// `Σ_i Σ_y |W[o,i,y]|`, and every column for input channel `i` has
/// `Σ_o Σ_y |W[o,i,y]|` — no matrix needed.
pub fn holder_from_taps(kernel: &ConvKernel) -> f64 {
    let mut row_sums = vec![0.0f64; kernel.c_out];
    let mut col_sums = vec![0.0f64; kernel.c_in];
    for o in 0..kernel.c_out {
        for i in 0..kernel.c_in {
            for r in 0..kernel.kh {
                for c in 0..kernel.kw {
                    let a = kernel.get(o, i, r, c).abs();
                    row_sums[o] += a;
                    col_sums[i] += a;
                }
            }
        }
    }
    let rmax = row_sums.iter().cloned().fold(0.0, f64::max);
    let cmax = col_sums.iter().cloned().fold(0.0, f64::max);
    (rmax * cmax).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::unroll_dense;
    use crate::linalg::norms;

    #[test]
    fn estimators_are_consistent() {
        let mut rng = Pcg64::seeded(180);
        let k = ConvKernel::random_he(4, 4, 3, 3, &mut rng);
        let rep = spectral_report(&k, 8, 8, Default::default());
        // Power iteration converges to the exact value — and says so.
        assert!(rep.power_converged, "power iteration should certify convergence here");
        assert!(
            (rep.exact_lfa - rep.power_iteration).abs() / rep.exact_lfa < 1e-6,
            "lfa {} vs power {}",
            rep.exact_lfa,
            rep.power_iteration
        );
        // The certified YM bound and Hölder are upper bounds.
        assert!(rep.ym_upper_bound >= rep.exact_lfa * (1.0 - 1e-9), "ym bound");
        assert!(rep.holder_bound >= rep.exact_lfa * (1.0 - 1e-9), "holder");
    }

    #[test]
    fn topk_norm_matches_exact() {
        let mut rng = Pcg64::seeded(183);
        let k = ConvKernel::random_he(5, 3, 3, 3, &mut rng);
        let exact = lfa::singular_values(&k, 10, 10, Default::default()).sigma_max();
        let (fast, iters) = sigma_max_topk(&k, 10, 10, Default::default());
        assert!((fast - exact).abs() <= 1e-8 * exact, "{fast} vs {exact}");
        assert!(iters > 0);
    }

    #[test]
    fn holder_from_taps_matches_matrix_norms() {
        let mut rng = Pcg64::seeded(181);
        let k = ConvKernel::random_he(3, 2, 3, 3, &mut rng);
        let a = unroll_dense(&k, 6, 6, Boundary::Periodic);
        let via_matrix = (norms::norm_1(&a) * norms::norm_inf(&a)).sqrt();
        let via_taps = holder_from_taps(&k);
        assert!((via_matrix - via_taps).abs() < 1e-10);
    }

    #[test]
    fn ym_certified_bound_is_loose() {
        // The certified √(k²)·σ_reshape bound strictly exceeds the exact
        // norm for generic kernels — "loose upper bound" in the paper's
        // wording.
        let mut rng = Pcg64::seeded(182);
        let k = ConvKernel::random_he(8, 8, 3, 3, &mut rng);
        let rep = spectral_report(&k, 16, 16, Default::default());
        assert!(rep.ym_upper_bound > rep.exact_lfa * 1.05, "should be visibly loose");
    }
}
