//! Pseudo-inverse of a convolutional mapping via its LFA SVD —
//! the application highlighted by the paper for pseudo-invertible networks
//! (Bolluyt & Comaniciu 2024): instead of their approximate restructuring,
//! the exact Moore–Penrose inverse `B = A⁺` drops out of the per-frequency
//! SVD as `B_k = V_k Σ_k⁺ U_kᴴ`.

use crate::conv::ConvKernel;
use crate::engine::SpectralPlan;
use crate::lfa::{BlockLayout, FullSvd, LfaOptions, SymbolGrid};
use crate::numeric::CMat;

/// The pseudo-inverse operator in frequency space.
pub struct PseudoInverse {
    /// Symbols of `A⁺` (`c_in×c_out` blocks).
    pub grid: SymbolGrid,
    /// Relative tolerance below which singular values are treated as zero.
    pub rcond: f64,
    /// Number of singular values zeroed by `rcond`.
    pub null_count: usize,
}

/// Build `A⁺` from a kernel on an `n×m` periodic grid.
pub fn pseudo_inverse(
    kernel: &ConvKernel,
    n: usize,
    m: usize,
    rcond: f64,
    opts: LfaOptions,
) -> PseudoInverse {
    let svd = SpectralPlan::new(kernel, n, m, opts).full_svd();
    pseudo_inverse_from_svd(&svd, rcond)
}

/// Build `A⁺` from an existing full SVD.
pub fn pseudo_inverse_from_svd(svd: &FullSvd, rcond: f64) -> PseudoInverse {
    let freqs = svd.sigma.n * svd.sigma.m;
    let r = svd.sigma.rank_per_freq();
    let cutoff = svd.sigma.sigma_max() * rcond;
    let mut null_count = 0usize;
    // Note the swap: blocks of A⁺ are c_in×c_out.
    let mut grid = SymbolGrid::zeros(
        svd.n,
        svd.m,
        svd.c_in,
        svd.c_out,
        BlockLayout::BlockContiguous,
    );
    for f in 0..freqs {
        let s = svd.sigma.at(f);
        let u = &svd.u[f];
        let v = &svd.v[f];
        // V Σ⁺ Uᴴ
        let mut vs = CMat::zeros(v.rows, r);
        for i in 0..v.rows {
            for j in 0..r {
                let inv = if s[j] > cutoff { 1.0 / s[j] } else { 0.0 };
                vs[(i, j)] = v[(i, j)].scale(inv);
            }
        }
        null_count += s.iter().filter(|&&x| x <= cutoff).count();
        let block = vs.matmul(&u.hermitian());
        grid.set_block(f, &block);
    }
    PseudoInverse { grid, rcond, null_count }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lfa::compute_symbols;
    use crate::numeric::Pcg64;
    use crate::spectral::freq_op::FreqOperator;

    #[test]
    fn pinv_of_full_rank_square_is_inverse() {
        let mut rng = Pcg64::seeded(170);
        let k = ConvKernel::random_he(3, 3, 3, 3, &mut rng);
        let (n, m) = (6, 6);
        let pinv = pseudo_inverse(&k, n, m, 1e-12, Default::default());
        assert_eq!(pinv.null_count, 0, "He-random 3x3 conv is a.s. full-rank");
        // A⁺ A f == f
        let grid = compute_symbols(&k, n, m, BlockLayout::BlockContiguous);
        let a = FreqOperator::new(&grid);
        let ap = FreqOperator::new(&pinv.grid);
        let f = rng.normal_vec(n * m * 3);
        let back = ap.apply(&a.apply(&f));
        for (x, y) in f.iter().zip(&back) {
            assert!((x - y).abs() < 1e-8, "{x} vs {y}");
        }
    }

    #[test]
    fn pinv_of_wide_conv_is_right_inverse() {
        // c_out < c_in: A A⁺ = I on the output space.
        let mut rng = Pcg64::seeded(171);
        let k = ConvKernel::random_he(2, 4, 3, 3, &mut rng);
        let (n, m) = (4, 4);
        let pinv = pseudo_inverse(&k, n, m, 1e-12, Default::default());
        let grid = compute_symbols(&k, n, m, BlockLayout::BlockContiguous);
        let a = FreqOperator::new(&grid);
        let ap = FreqOperator::new(&pinv.grid);
        let g = rng.normal_vec(n * m * 2);
        let again = a.apply(&ap.apply(&g));
        for (x, y) in g.iter().zip(&again) {
            assert!((x - y).abs() < 1e-8);
        }
    }

    #[test]
    fn pinv_projects_for_tall_conv() {
        // c_out > c_in: A⁺ A = I on the input space.
        let mut rng = Pcg64::seeded(172);
        let k = ConvKernel::random_he(5, 2, 3, 3, &mut rng);
        let (n, m) = (4, 4);
        let pinv = pseudo_inverse(&k, n, m, 1e-12, Default::default());
        let grid = compute_symbols(&k, n, m, BlockLayout::BlockContiguous);
        let a = FreqOperator::new(&grid);
        let ap = FreqOperator::new(&pinv.grid);
        let f = rng.normal_vec(n * m * 2);
        let back = ap.apply(&a.apply(&f));
        for (x, y) in f.iter().zip(&back) {
            assert!((x - y).abs() < 1e-8);
        }
    }

    #[test]
    fn rcond_zeroes_small_values() {
        // Rank-deficient by construction: second output channel = first.
        let mut rng = Pcg64::seeded(173);
        let mut k = ConvKernel::random_he(2, 2, 3, 3, &mut rng);
        for i in 0..2 {
            for r in 0..3 {
                for c in 0..3 {
                    let v = k.get(0, i, r, c);
                    k.set(1, i, r, c, v);
                }
            }
        }
        let pinv = pseudo_inverse(&k, 4, 4, 1e-10, Default::default());
        assert_eq!(pinv.null_count, 16, "one zero σ per frequency");
    }
}
