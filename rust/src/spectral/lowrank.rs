//! Low-rank approximation of convolutional mappings for model compression
//! (§II-c: Jaderberg et al., Zhang et al., Denton et al.).
//!
//! Per frequency, truncate `A_k` to its top-`r` singular triplets. The
//! relative approximation error has the closed Eckart–Young form
//! `√(Σ_{k,j>r} σ_{k,j}² / Σ σ²)`, and the compressed operator can be
//! stored as `n·m` factor pairs or re-projected onto a local kernel.

use crate::conv::ConvKernel;
use crate::engine::SpectralPlan;
use crate::lfa::{BlockLayout, FullSvd, LfaOptions, SymbolGrid, TopKSvd};
use crate::numeric::CMat;

/// A rank-`r` compressed convolution in frequency space.
pub struct LowRankConv {
    pub rank: usize,
    /// Truncated symbol grid (rank-`r` blocks).
    pub grid: SymbolGrid,
    /// Relative Frobenius error of the truncation (Eckart–Young optimal).
    pub rel_error: f64,
    /// Storage ratio vs the dense symbol grid:
    /// `r(c_out+c_in+1) / (c_out·c_in)`.
    pub storage_ratio: f64,
}

/// Truncate every frequency block to rank `r` (planned `FullSvd` path).
pub fn compress(
    kernel: &ConvKernel,
    n: usize,
    m: usize,
    r: usize,
    opts: LfaOptions,
) -> LowRankConv {
    let svd = SpectralPlan::new(kernel, n, m, opts).full_svd();
    compress_from_svd(&svd, r)
}

/// [`compress`] through the **top-k engine**: per frequency, only the `r`
/// kept triplets are ever computed (warm-started Krylov iteration,
/// `O(n·m·c²r)`) instead of the full decomposition (`O(n·m·c³)`). The
/// reported Eckart–Young error is still exact — the sweep accumulates the
/// total spectral energy from the symbol blocks directly.
pub fn compress_topk(
    kernel: &ConvKernel,
    n: usize,
    m: usize,
    r: usize,
    opts: LfaOptions,
) -> LowRankConv {
    let svd = SpectralPlan::new(kernel, n, m, opts).topk_svd(r);
    compress_from_topk(&svd)
}

/// Build the rank-`k` compressed operator from an existing partial SVD:
/// the truncated grid is `U_k Σ_k V_kᴴ` per frequency (Eckart–Young
/// optimal), and the relative error comes from the energy the truncation
/// dropped: `√(1 − Σ_kept σ² / Σ_total σ²)`.
pub fn compress_from_topk(svd: &TopKSvd) -> LowRankConv {
    let freqs = svd.sigma.n * svd.sigma.m;
    let r = svd.k;
    let mut grid = SymbolGrid::zeros(
        svd.n,
        svd.m,
        svd.c_out,
        svd.c_in,
        BlockLayout::BlockContiguous,
    );
    let mut kept = 0.0f64;
    for f in 0..freqs {
        for &sv in svd.sigma.at(f) {
            kept += sv * sv;
        }
        grid.set_block(f, &svd.truncated_symbol(f));
    }
    let total = svd.total_energy;
    let rel_error =
        if total > 0.0 { ((total - kept) / total).max(0.0).sqrt() } else { 0.0 };
    let storage_ratio =
        (r * (svd.c_out + svd.c_in + 1)) as f64 / (svd.c_out * svd.c_in) as f64;
    LowRankConv { rank: r, grid, rel_error, storage_ratio }
}

/// Truncate an existing full SVD to rank `r` per frequency.
pub fn compress_from_svd(svd: &FullSvd, r: usize) -> LowRankConv {
    let freqs = svd.sigma.n * svd.sigma.m;
    let rank_full = svd.sigma.rank_per_freq();
    let r = r.min(rank_full);
    let mut grid = SymbolGrid::zeros(
        svd.n,
        svd.m,
        svd.c_out,
        svd.c_in,
        BlockLayout::BlockContiguous,
    );
    let mut kept = 0.0f64;
    let mut total = 0.0f64;
    for f in 0..freqs {
        let s = svd.sigma.at(f);
        for (j, &sv) in s.iter().enumerate() {
            total += sv * sv;
            if j < r {
                kept += sv * sv;
            }
        }
        let u = &svd.u[f];
        let v = &svd.v[f];
        let mut us = CMat::zeros(u.rows, r);
        for i in 0..u.rows {
            for j in 0..r {
                us[(i, j)] = u[(i, j)].scale(s[j]);
            }
        }
        let mut vr = CMat::zeros(v.rows, r);
        for i in 0..v.rows {
            for j in 0..r {
                vr[(i, j)] = v[(i, j)];
            }
        }
        let block = us.matmul(&vr.hermitian());
        grid.set_block(f, &block);
    }
    let rel_error = if total > 0.0 { ((total - kept) / total).max(0.0).sqrt() } else { 0.0 };
    let storage_ratio =
        (r * (svd.c_out + svd.c_in + 1)) as f64 / (svd.c_out * svd.c_in) as f64;
    LowRankConv { rank: r, grid, rel_error, storage_ratio }
}

/// Sweep ranks `1..=min(c_out,c_in)` and report `(rank, rel_error,
/// storage_ratio)` — the compression trade-off curve.
pub fn rank_sweep(
    kernel: &ConvKernel,
    n: usize,
    m: usize,
    opts: LfaOptions,
) -> Vec<(usize, f64, f64)> {
    let svd = SpectralPlan::new(kernel, n, m, opts).full_svd();
    let rmax = svd.sigma.rank_per_freq();
    (1..=rmax)
        .map(|r| {
            let c = compress_from_svd(&svd, r);
            (r, c.rel_error, c.storage_ratio)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lfa::compute_symbols;
    use crate::numeric::Pcg64;
    use crate::spectral::freq_op::FreqOperator;

    #[test]
    fn full_rank_is_lossless() {
        let mut rng = Pcg64::seeded(160);
        let k = ConvKernel::random_he(3, 3, 3, 3, &mut rng);
        let c = compress(&k, 6, 6, 3, Default::default());
        assert!(c.rel_error < 1e-12);
        let exact = compute_symbols(&k, 6, 6, BlockLayout::BlockContiguous);
        assert!(c.grid.max_abs_diff(&exact) < 1e-10);
    }

    #[test]
    fn topk_compression_matches_full_route() {
        let mut rng = Pcg64::seeded(164);
        let k = ConvKernel::random_he(4, 4, 3, 3, &mut rng);
        let full = compress(&k, 6, 6, 2, Default::default());
        let fast = compress_topk(&k, 6, 6, 2, Default::default());
        assert_eq!(fast.rank, 2);
        assert!(
            (full.rel_error - fast.rel_error).abs() < 1e-8,
            "{} vs {}",
            full.rel_error,
            fast.rel_error
        );
        assert!((full.storage_ratio - fast.storage_ratio).abs() < 1e-12);
        assert!(full.grid.max_abs_diff(&fast.grid) < 1e-6, "same truncated operator");
    }

    #[test]
    fn error_decreases_with_rank() {
        let mut rng = Pcg64::seeded(161);
        let k = ConvKernel::random_he(4, 4, 3, 3, &mut rng);
        let sweep = rank_sweep(&k, 8, 8, Default::default());
        assert_eq!(sweep.len(), 4);
        for w in sweep.windows(2) {
            assert!(w[0].1 >= w[1].1, "error must shrink with rank: {sweep:?}");
        }
        assert!(sweep[3].1 < 1e-12);
    }

    #[test]
    fn eckart_young_error_matches_operator_error() {
        // Relative spectral-energy error == relative operator Frobenius
        // error measured by applying both operators to a basis of inputs.
        let mut rng = Pcg64::seeded(162);
        let k = ConvKernel::random_he(3, 3, 3, 3, &mut rng);
        let (n, m) = (4, 4);
        let c = compress(&k, n, m, 1, Default::default());
        let exact = compute_symbols(&k, n, m, BlockLayout::BlockContiguous);
        let f_exact = FreqOperator::new(&exact);
        let f_low = FreqOperator::new(&c.grid);
        let dim = n * m * 3;
        let mut num = 0.0;
        let mut den = 0.0;
        for b in 0..dim {
            let mut e = vec![0.0; dim];
            e[b] = 1.0;
            let y1 = f_exact.apply(&e);
            let y2 = f_low.apply(&e);
            num += y1.iter().zip(&y2).map(|(a, b)| (a - b) * (a - b)).sum::<f64>();
            den += y1.iter().map(|a| a * a).sum::<f64>();
        }
        let measured = (num / den).sqrt();
        assert!(
            (measured - c.rel_error).abs() < 1e-8,
            "measured {measured} vs eckart-young {}",
            c.rel_error
        );
    }

    #[test]
    fn storage_ratio_model() {
        let mut rng = Pcg64::seeded(163);
        let k = ConvKernel::random_he(8, 4, 3, 3, &mut rng);
        let c = compress(&k, 4, 4, 2, Default::default());
        assert!((c.storage_ratio - (2.0 * 13.0 / 32.0)).abs() < 1e-12);
    }
}
