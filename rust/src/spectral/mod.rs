//! Applications of the convolutional SVD (§II-c of the paper): spectral
//! clipping for regularization/robustness, low-rank compression,
//! Moore–Penrose pseudo-inverse, and spectral-norm estimator comparisons.

pub mod clip;
pub mod freq_op;
pub mod lipschitz;
pub mod lowrank;
pub mod pinv;

pub use clip::{clip_spectral_norm, clip_with_plan, ClipResult};
pub use freq_op::FreqOperator;
pub use lipschitz::{spectral_report, SpectralNormReport};
pub use lowrank::{compress, rank_sweep, LowRankConv};
pub use pinv::{pseudo_inverse, PseudoInverse};
