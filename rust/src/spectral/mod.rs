//! Applications of the convolutional SVD (§II-c of the paper): spectral
//! clipping for regularization/robustness, low-rank compression,
//! Moore–Penrose pseudo-inverse, and spectral-norm estimator comparisons.
//!
//! The applications that only consume extreme singular values route
//! through the engine's top-k partial-spectrum mode where it pays:
//! [`clip::needs_clipping`] (top-1 screening before a full clip),
//! [`lipschitz::sigma_max_topk`] (exact norm without the full
//! decomposition), and [`lowrank::compress_topk`] (only the kept triplets
//! are ever computed).

pub mod clip;
pub mod freq_op;
pub mod lipschitz;
pub mod lowrank;
pub mod pinv;

pub use clip::{clip_spectral_norm, clip_with_plan, needs_clipping, ClipResult};
pub use freq_op::FreqOperator;
pub use lipschitz::{sigma_max_topk, spectral_report, SpectralNormReport};
pub use lowrank::{compress, compress_topk, rank_sweep, LowRankConv};
pub use pinv::{pseudo_inverse, PseudoInverse};
