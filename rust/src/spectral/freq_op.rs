//! Apply a frequency-domain operator (a symbol grid) to spatial feature
//! maps: `g = F⁻¹ · diag(A_k) · F f`.
//!
//! This is how spectrally-edited operators (clipped, truncated, inverted)
//! act on data without ever leaving the `O(n·m·c²)`-per-application regime —
//! the global singular vectors `F_k U_k` are applied implicitly via FFTs.
//! The symbol grids consumed here come from the planned `FullSvd` path
//! (`SpectralPlan::full_svd` → `map_singular_values` and friends).

use crate::fft::{Direction, FftPlan};
use crate::lfa::SymbolGrid;
use crate::numeric::C64;

/// A convolution-like operator given by its per-frequency symbols.
pub struct FreqOperator<'a> {
    pub grid: &'a SymbolGrid,
}

impl<'a> FreqOperator<'a> {
    pub fn new(grid: &'a SymbolGrid) -> Self {
        Self { grid }
    }

    pub fn in_len(&self) -> usize {
        self.grid.n * self.grid.m * self.grid.c_in
    }

    pub fn out_len(&self) -> usize {
        self.grid.n * self.grid.m * self.grid.c_out
    }

    /// Apply to a real feature map in spatial-major channel-minor order
    /// (same convention as [`crate::conv::ConvOp::forward`]). Exact for
    /// periodic boundary conditions.
    pub fn apply(&self, f: &[f64]) -> Vec<f64> {
        let (n, m) = (self.grid.n, self.grid.m);
        let (cin, cout) = (self.grid.c_in, self.grid.c_out);
        assert_eq!(f.len(), n * m * cin, "input length mismatch");
        let nm = n * m;
        // Per-channel forward FFT of the input.
        let mut fhat = vec![C64::ZERO; nm * cin];
        let row_plan = FftPlan::new(m);
        let col_plan = FftPlan::new(n);
        let mut plane = vec![C64::ZERO; nm];
        let mut scratch = vec![C64::ZERO; n];
        for i in 0..cin {
            for x in 0..nm {
                plane[x] = C64::real(f[x * cin + i]);
            }
            fft2_inplace(&mut plane, n, m, &row_plan, &col_plan, &mut scratch, Direction::Forward);
            for x in 0..nm {
                fhat[x * cin + i] = plane[x];
            }
        }
        // Per-frequency block matvec: ĝ_k = A_k f̂_k.
        let mut ghat = vec![C64::ZERO; nm * cout];
        for k in 0..nm {
            for o in 0..cout {
                let mut acc = C64::ZERO;
                for i in 0..cin {
                    acc = acc.mul_add(self.grid.get(k, o, i), fhat[k * cin + i]);
                }
                ghat[k * cout + o] = acc;
            }
        }
        // Per-channel inverse FFT.
        let mut out = vec![0.0f64; nm * cout];
        for o in 0..cout {
            for x in 0..nm {
                plane[x] = ghat[x * cout + o];
            }
            fft2_inplace(&mut plane, n, m, &row_plan, &col_plan, &mut scratch, Direction::Inverse);
            for x in 0..nm {
                out[x * cout + o] = plane[x].re;
            }
        }
        out
    }
}

fn fft2_inplace(
    plane: &mut [C64],
    n: usize,
    m: usize,
    row_plan: &FftPlan,
    col_plan: &FftPlan,
    scratch: &mut [C64],
    dir: Direction,
) {
    for r in 0..n {
        row_plan.transform(&mut plane[r * m..(r + 1) * m], dir);
    }
    for c in 0..m {
        for r in 0..n {
            scratch[r] = plane[r * m + c];
        }
        col_plan.transform(scratch, dir);
        for r in 0..n {
            plane[r * m + c] = scratch[r];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::{Boundary, ConvKernel, ConvOp};
    use crate::lfa::{compute_symbols, BlockLayout};
    use crate::numeric::Pcg64;

    #[test]
    fn matches_direct_periodic_convolution() {
        let mut rng = Pcg64::seeded(140);
        let k = ConvKernel::random_he(3, 2, 3, 3, &mut rng);
        for (n, m) in [(4usize, 4usize), (8, 6), (5, 5)] {
            let grid = compute_symbols(&k, n, m, BlockLayout::BlockContiguous);
            let fop = FreqOperator::new(&grid);
            let op = ConvOp::new(&k, n, m, Boundary::Periodic);
            let f = rng.normal_vec(n * m * 2);
            let g1 = op.forward(&f);
            let g2 = fop.apply(&f);
            for (a, b) in g1.iter().zip(&g2) {
                assert!((a - b).abs() < 1e-10, "({n},{m}): {a} vs {b}");
            }
        }
    }

    #[test]
    fn identity_grid_is_identity() {
        let mut k = ConvKernel::zeros(2, 2, 1, 1);
        k.set(0, 0, 0, 0, 1.0);
        k.set(1, 1, 0, 0, 1.0);
        let grid = compute_symbols(&k, 4, 4, BlockLayout::BlockContiguous);
        let fop = FreqOperator::new(&grid);
        let mut rng = Pcg64::seeded(141);
        let f = rng.normal_vec(32);
        let g = fop.apply(&f);
        for (a, b) in f.iter().zip(&g) {
            assert!((a - b).abs() < 1e-12);
        }
    }
}
