//! Lightweight atomic metrics for the coordinator (no external deps).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Shared counters. All methods are lock-free; snapshot with [`Metrics::snapshot`].
#[derive(Default)]
pub struct Metrics {
    pub jobs_submitted: AtomicU64,
    pub jobs_completed: AtomicU64,
    pub jobs_failed: AtomicU64,
    pub tiles_dispatched: AtomicU64,
    pub tiles_completed: AtomicU64,
    pub values_computed: AtomicU64,
    /// Nanoseconds spent inside per-tile work, summed over workers.
    pub tile_work_nanos: AtomicU64,
    /// Tiles executed on the PJRT backend.
    pub pjrt_tiles: AtomicU64,
    /// Tiles executed natively.
    pub native_tiles: AtomicU64,
    /// Jobs (per-layer for model jobs) served from the result cache —
    /// zero frequencies re-solved.
    pub cache_hits: AtomicU64,
    /// Cacheable jobs that missed and were computed (then inserted).
    pub cache_misses: AtomicU64,
    /// Result-cache entries evicted under the byte budget.
    pub cache_evictions: AtomicU64,
    /// Frequencies still unconverged after the full escalation ladder
    /// (their spectra ship flagged and are refused by the cache).
    pub degraded_freqs: AtomicU64,
    /// Escalation-ladder rungs taken (full-Jacobi / f64 re-solves of
    /// frequencies whose first-tier certificate missed tolerance).
    pub lfa_escalations: AtomicU64,
    /// Submissions rejected at the non-finite weight screen, before any
    /// frequency was solved (never counted in `jobs_submitted`).
    pub nonfinite_rejections: AtomicU64,
}

/// A point-in-time copy of the counters.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MetricsSnapshot {
    pub jobs_submitted: u64,
    pub jobs_completed: u64,
    pub jobs_failed: u64,
    pub tiles_dispatched: u64,
    pub tiles_completed: u64,
    pub values_computed: u64,
    pub tile_work: Duration,
    pub pjrt_tiles: u64,
    pub native_tiles: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_evictions: u64,
    /// Frequencies still unconverged after the escalation ladder.
    pub degraded_freqs: u64,
    /// Escalation-ladder rungs taken across all jobs.
    pub escalations: u64,
    /// Submissions rejected for NaN/Inf weights before any solve.
    pub nonfinite_rejections: u64,
    /// Disk-tier lookups served from a valid spill file (0 unless a
    /// `disk_cache_dir` is configured). Filled in by
    /// [`crate::coordinator::SpectralService::metrics`] from the cache's
    /// own counters — the `Metrics` struct stays purely scheduler-side.
    pub disk_hits: u64,
    /// Disk-tier lookups that found no spill file.
    pub disk_misses: u64,
    /// Spectra newly spilled to disk.
    pub disk_spills: u64,
    /// Spill files that failed validation and were quarantined.
    pub disk_corruptions: u64,
}

impl Metrics {
    pub fn record_tile(&self, values: usize, elapsed: Duration, pjrt: bool) {
        self.tiles_completed.fetch_add(1, Ordering::Relaxed);
        self.values_computed.fetch_add(values as u64, Ordering::Relaxed);
        self.tile_work_nanos.fetch_add(elapsed.as_nanos() as u64, Ordering::Relaxed);
        if pjrt {
            self.pjrt_tiles.fetch_add(1, Ordering::Relaxed);
        } else {
            self.native_tiles.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            jobs_submitted: self.jobs_submitted.load(Ordering::Relaxed),
            jobs_completed: self.jobs_completed.load(Ordering::Relaxed),
            jobs_failed: self.jobs_failed.load(Ordering::Relaxed),
            tiles_dispatched: self.tiles_dispatched.load(Ordering::Relaxed),
            tiles_completed: self.tiles_completed.load(Ordering::Relaxed),
            values_computed: self.values_computed.load(Ordering::Relaxed),
            tile_work: Duration::from_nanos(self.tile_work_nanos.load(Ordering::Relaxed)),
            pjrt_tiles: self.pjrt_tiles.load(Ordering::Relaxed),
            native_tiles: self.native_tiles.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.cache_misses.load(Ordering::Relaxed),
            cache_evictions: self.cache_evictions.load(Ordering::Relaxed),
            degraded_freqs: self.degraded_freqs.load(Ordering::Relaxed),
            escalations: self.lfa_escalations.load(Ordering::Relaxed),
            nonfinite_rejections: self.nonfinite_rejections.load(Ordering::Relaxed),
            disk_hits: 0,
            disk_misses: 0,
            disk_spills: 0,
            disk_corruptions: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::default();
        m.jobs_submitted.fetch_add(2, Ordering::Relaxed);
        m.record_tile(64, Duration::from_millis(3), true);
        m.record_tile(64, Duration::from_millis(2), false);
        m.degraded_freqs.fetch_add(1, Ordering::Relaxed);
        m.lfa_escalations.fetch_add(2, Ordering::Relaxed);
        m.nonfinite_rejections.fetch_add(3, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.degraded_freqs, 1);
        assert_eq!(s.escalations, 2);
        assert_eq!(s.nonfinite_rejections, 3);
        assert_eq!(s.jobs_submitted, 2);
        assert_eq!(s.tiles_completed, 2);
        assert_eq!(s.values_computed, 128);
        assert_eq!(s.pjrt_tiles, 1);
        assert_eq!(s.native_tiles, 1);
        assert_eq!(s.tile_work, Duration::from_millis(5));
    }
}
