//! `lfa-convd` — the long-running spectral-audit daemon (feature
//! `daemon`, on by default; the CLI's `serve` subcommand).
//!
//! The ROADMAP's "millions of users" need more than the in-process
//! [`SpectralService`]: a server that survives between audits, shares one
//! warm [`crate::engine::SpectralCache`] (plus its persistent disk tier)
//! across all clients, and keeps one flooding tenant from starving the
//! rest. This module is that server, std-only:
//!
//! - **Loopback TCP front-end** with a minimal line protocol (one request
//!   line, one reply line — trivially scriptable from shell/python) plus a
//!   plain-HTTP `GET /metrics` endpoint rendered from
//!   [`super::MetricsSnapshot`] for scrapers.
//! - **Per-tenant admission control**: each `SUBMIT` names a tenant; a
//!   tenant with `tenant_quota` jobs already queued + running is rejected
//!   with a *typed* backpressure reply (`ERR quota tenant=… pending=…
//!   limit=…`) instead of being queued behind everyone else's flood.
//! - **Deficit-round-robin fair queueing** ([`FairQueue`]): admitted jobs
//!   are dispatched to the scheduler in DRR order — each round, every
//!   tenant's deficit counter grows by one quantum and a tenant may spend
//!   its deficit on jobs (cost = layer count), so tenants get equal
//!   *cost* shares no matter how asymmetric their submission rates are,
//!   and a well-behaved tenant's job is served within a bounded number of
//!   rounds of arriving.
//! - **Request timeouts with cancellation**: every job carries a deadline;
//!   a job still queued past it is cancelled without running, a job that
//!   finishes past it reports `ERR timeout` and its result is discarded.
//!   Connections that go quiet are closed after `io_timeout`
//!   (slow-consumer protection); a client disconnecting mid-request
//!   leaves the daemon — and its submitted jobs, pollable from any new
//!   connection — untouched.
//!
//! ### Protocol
//!
//! ```text
//! >> PING
//! << PONG
//! >> SUBMIT tenant-a lenet [top-k=K | density=B [density-sample=S]]
//!                                             (builtin name or config.toml path;
//!                                              density=B streams a B-bin histogram,
//!                                              density-sample=S solves every S-th row/col)
//! << QUEUED id=1 tenant=tenant-a cost=2       | ERR quota tenant=… pending=… limit=…
//! >> POLL 1
//! << PENDING id=1 | RUNNING id=1 | DONE id=1 layers=… sigma_max=… solved=… cached=… elapsed_ms=…
//!    (density jobs append density_bins=B sample=S coverage=… epsilon=…)
//!    | ERR timeout id=1 | ERR failed id=1 … | ERR unknown-job id=1
//!    | ERR nonfinite id=1 layer=… count=…   (NaN/Inf weights, screened pre-solve)
//!    | ERR degraded job=1 freqs=…           (strict-health: unconverged after escalation)
//! >> WAIT 1                                   (blocks until terminal or deadline)
//! << DONE id=1 …
//! >> METRICS                                  (one line of key=value pairs)
//! >> STATS                                    (cache + density + disk-tier counters)
//! >> RESUME                                   (release a start_paused daemon)
//! >> QUIT | SHUTDOWN
//! GET /metrics HTTP/1.1                       (plain-HTTP scrape: lfa_* lines)
//! ```
//!
//! The daemon trusts its socket (bind it to loopback, the default): model
//! tokens may name builtin zoo models or readable TOML config paths.

use super::service::{ServiceConfig, SpectralService};
use crate::engine::{DensityRequest, SpectrumRequest};
use crate::error::{Context, Result};
use crate::report;
use crate::model::config::ModelConfig;
use crate::model::zoo;
use crate::{bail, err};
use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Daemon configuration ([`serve`]).
#[derive(Clone)]
pub struct DaemonConfig {
    /// The wrapped service (workers, precision, cache budget,
    /// `disk_cache_dir`, `tenant_quota`, …).
    pub service: ServiceConfig,
    /// Bind address; use port 0 to let the OS pick (the bound address is
    /// on [`DaemonHandle::addr`]). Keep it loopback — the protocol is
    /// unauthenticated by design.
    pub addr: String,
    /// Concurrent jobs dispatched into the scheduler (runner threads);
    /// 0 = default (2). The scheduler's own worker pool parallelizes
    /// *within* a job; this bounds cross-job concurrency.
    pub max_inflight: usize,
    /// Per-job deadline measured from admission (zero = default 30 s).
    pub request_timeout: Duration,
    /// Socket idle/read timeout — a connection that sends nothing for
    /// this long gets a slow-consumer reply and is closed (zero =
    /// default 10 s).
    pub io_timeout: Duration,
    /// DRR quantum in cost units (cost = a job's layer count); 0 =
    /// default (8). Larger quanta let expensive multi-layer jobs through
    /// in fewer rounds at slightly coarser interleaving.
    pub quantum: usize,
    /// Start with dispatch held: jobs are admitted (quota decisions are
    /// made) but nothing runs until a `RESUME` command. Admission
    /// decisions made while paused depend only on arrival order — the
    /// fairness suite uses this to prove serial and threaded schedulers
    /// admit identically.
    pub start_paused: bool,
}

impl Default for DaemonConfig {
    fn default() -> Self {
        Self {
            service: ServiceConfig::default(),
            addr: "127.0.0.1:0".to_string(),
            max_inflight: 0,
            request_timeout: Duration::ZERO,
            io_timeout: Duration::ZERO,
            quantum: 0,
            start_paused: false,
        }
    }
}

impl DaemonConfig {
    const DEFAULT_MAX_INFLIGHT: usize = 2;
    const DEFAULT_REQUEST_TIMEOUT: Duration = Duration::from_secs(30);
    const DEFAULT_IO_TIMEOUT: Duration = Duration::from_secs(10);
    const DEFAULT_QUANTUM: usize = 8;

    fn effective_max_inflight(&self) -> usize {
        if self.max_inflight == 0 {
            Self::DEFAULT_MAX_INFLIGHT
        } else {
            self.max_inflight
        }
    }

    fn effective_request_timeout(&self) -> Duration {
        if self.request_timeout.is_zero() {
            Self::DEFAULT_REQUEST_TIMEOUT
        } else {
            self.request_timeout
        }
    }

    fn effective_io_timeout(&self) -> Duration {
        if self.io_timeout.is_zero() {
            Self::DEFAULT_IO_TIMEOUT
        } else {
            self.io_timeout
        }
    }

    fn effective_quantum(&self) -> usize {
        if self.quantum == 0 {
            Self::DEFAULT_QUANTUM
        } else {
            self.quantum
        }
    }
}

/// Typed admission rejection ([`FairQueue::try_enqueue`]) — the payload of
/// the `ERR quota` backpressure reply.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct QuotaExceeded {
    /// The tenant that was rejected (quotas are strictly per-tenant: one
    /// tenant flooding never consumes another's admission budget).
    pub tenant: String,
    /// Jobs this tenant already has queued + running.
    pub pending: usize,
    /// The per-tenant quota that was hit.
    pub quota: usize,
}

struct TenantState {
    name: String,
    /// FIFO of admitted jobs: (job id, cost).
    queue: VecDeque<(u64, usize)>,
    /// DRR deficit counter (cost units this tenant may spend).
    deficit: usize,
    /// Jobs popped but not yet completed.
    in_flight: usize,
}

/// Deficit-round-robin fair queue with per-tenant admission quotas.
///
/// Deterministic by construction: decisions depend only on the sequence
/// of `try_enqueue` / `next` / `complete` calls (tenant order is
/// registration order, ties break by round-robin cursor) — never on
/// thread timing — so a serial and a threaded scheduler given the same
/// call sequence admit and dispatch identically. Exposed `pub` for the
/// fairness property suite (`tests/fairness.rs`).
pub struct FairQueue {
    quota: usize,
    quantum: usize,
    tenants: Vec<TenantState>,
    index: HashMap<String, usize>,
    cursor: usize,
}

impl FairQueue {
    /// `quota` = max queued + running jobs per tenant; `quantum` = DRR
    /// refill per round (cost units). Both are clamped to ≥ 1.
    pub fn new(quota: usize, quantum: usize) -> Self {
        Self {
            quota: quota.max(1),
            quantum: quantum.max(1),
            tenants: Vec::new(),
            index: HashMap::new(),
            cursor: 0,
        }
    }

    fn tenant_index(&mut self, tenant: &str) -> usize {
        if let Some(&i) = self.index.get(tenant) {
            return i;
        }
        self.tenants.push(TenantState {
            name: tenant.to_string(),
            queue: VecDeque::new(),
            deficit: 0,
            in_flight: 0,
        });
        self.index.insert(tenant.to_string(), self.tenants.len() - 1);
        self.tenants.len() - 1
    }

    /// Admit a job, or reject it with the typed quota error. `cost` is
    /// the job's DRR weight (layer count; clamped to ≥ 1).
    pub fn try_enqueue(
        &mut self,
        tenant: &str,
        id: u64,
        cost: usize,
    ) -> std::result::Result<(), QuotaExceeded> {
        let quota = self.quota;
        let i = self.tenant_index(tenant);
        let t = &mut self.tenants[i];
        let pending = t.queue.len() + t.in_flight;
        if pending >= quota {
            return Err(QuotaExceeded { tenant: tenant.to_string(), pending, quota });
        }
        t.queue.push_back((id, cost.max(1)));
        Ok(())
    }

    /// Pop the next job in DRR order: the cursor sweeps tenants round-
    /// robin; visiting a non-empty tenant refills its deficit by one
    /// quantum, and the tenant serves its FIFO head once the deficit
    /// covers the head's cost. Idle tenants forfeit their deficit
    /// (standard DRR — credit must not accumulate while a queue is
    /// empty). Returns `None` only when every queue is empty; otherwise
    /// termination is guaranteed because some deficit grows every round.
    pub fn pop(&mut self) -> Option<(u64, String)> {
        if self.tenants.iter().all(|t| t.queue.is_empty()) {
            return None;
        }
        let n = self.tenants.len();
        loop {
            let i = self.cursor % n;
            self.cursor = (self.cursor + 1) % n;
            let t = &mut self.tenants[i];
            if t.queue.is_empty() {
                t.deficit = 0;
                continue;
            }
            t.deficit = t.deficit.saturating_add(self.quantum);
            let head_cost = t.queue.front().expect("non-empty queue").1;
            if t.deficit >= head_cost {
                let (id, cost) = t.queue.pop_front().expect("non-empty queue");
                t.deficit -= cost;
                if t.queue.is_empty() {
                    t.deficit = 0;
                }
                t.in_flight += 1;
                return Some((id, t.name.clone()));
            }
        }
    }

    /// Mark one of `tenant`'s in-flight jobs finished (frees quota).
    pub fn complete(&mut self, tenant: &str) {
        if let Some(&i) = self.index.get(tenant) {
            let t = &mut self.tenants[i];
            t.in_flight = t.in_flight.saturating_sub(1);
        }
    }

    /// Jobs `tenant` has queued + running.
    pub fn pending(&self, tenant: &str) -> usize {
        match self.index.get(tenant) {
            Some(&i) => self.tenants[i].queue.len() + self.tenants[i].in_flight,
            None => 0,
        }
    }

    /// Jobs queued (not yet dispatched) across all tenants.
    pub fn queued_total(&self) -> usize {
        self.tenants.iter().map(|t| t.queue.len()).sum()
    }

    /// Tenants ever registered.
    pub fn tenant_count(&self) -> usize {
        self.tenants.len()
    }
}

/// What a queued job will run: a spectrum sweep (full or top-k) or a
/// streaming density sweep.
#[derive(Clone, Copy)]
enum JobRequest {
    Spectrum(SpectrumRequest),
    Density(DensityRequest),
}

/// What a queued job will run.
struct PendingSpec {
    model: ModelConfig,
    request: JobRequest,
}

/// Density tail of a `DONE` reply (`SUBMIT … density=B` jobs): the
/// accuracy contract on the wire — worst per-layer coverage fraction and
/// the largest 95% DKW CDF half-width across layers.
#[derive(Clone)]
struct DensitySummary {
    bins: u32,
    sample: u32,
    coverage: f64,
    epsilon: f64,
}

/// Terminal summary of a completed job (the `DONE` reply payload).
#[derive(Clone)]
struct JobSummary {
    layers: usize,
    sigma_max: f64,
    solved_freqs: usize,
    cached_layers: usize,
    elapsed_ms: u128,
    /// `Some` for density jobs — appended to the `DONE` line.
    density: Option<DensitySummary>,
}

#[derive(Clone)]
enum JobPhase {
    Queued,
    Running,
    Done(JobSummary),
    /// Holds the complete wire tail after `ERR ` — already classified
    /// (`nonfinite …` / `degraded …` / `failed …`) by [`failure_tail`].
    Failed(String),
    TimedOut,
}

/// Map a job error to its `ERR ` wire tail. Typed numerical-health
/// failures keep their structure on the wire so clients can dispatch on
/// the first token instead of parsing prose:
///
/// - [`crate::ErrorKind::NonFiniteWeights`] → `nonfinite id=… layer=… count=…`
/// - [`crate::ErrorKind::DegradedSpectrum`] → `degraded job=… freqs=…`
/// - everything else → `failed id=… <message>`
fn failure_tail(id: u64, why: &crate::error::Error) -> String {
    use crate::error::ErrorKind as Kind;
    match why.kind() {
        Kind::NonFiniteWeights { layer, count } => {
            format!("nonfinite id={id} layer={layer} count={count}")
        }
        Kind::DegradedSpectrum { freqs, .. } => format!("degraded job={id} freqs={freqs}"),
        Kind::Generic => format!("failed id={id} {why}"),
    }
}

struct JobEntry {
    tenant: String,
    deadline: Instant,
    phase: JobPhase,
}

struct QueueState {
    fair: FairQueue,
    specs: HashMap<u64, PendingSpec>,
    paused: bool,
}

struct Shared {
    svc: SpectralService,
    addr: SocketAddr,
    queue: Mutex<QueueState>,
    queue_cv: Condvar,
    jobs: Mutex<HashMap<u64, JobEntry>>,
    jobs_cv: Condvar,
    next_id: AtomicU64,
    stopping: AtomicBool,
    quota_rejections: AtomicU64,
    request_timeout: Duration,
    io_timeout: Duration,
}

impl Shared {
    fn lock_queue(&self) -> MutexGuard<'_, QueueState> {
        self.queue.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn lock_jobs(&self) -> MutexGuard<'_, HashMap<u64, JobEntry>> {
        self.jobs.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn stop(&self) {
        if self.stopping.swap(true, Ordering::SeqCst) {
            return;
        }
        self.queue_cv.notify_all();
        self.jobs_cv.notify_all();
        // Wake the acceptor out of its blocking accept().
        let _ = TcpStream::connect(self.addr);
    }
}

/// Handle to a running daemon: the bound address plus join/shutdown.
pub struct DaemonHandle {
    shared: Arc<Shared>,
    addr: SocketAddr,
    acceptor: std::thread::JoinHandle<()>,
    runners: Vec<std::thread::JoinHandle<()>>,
}

impl DaemonHandle {
    /// The address the daemon actually bound (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Block until the daemon stops (a `SHUTDOWN` command, or
    /// [`Self::shutdown`] from another thread via a cloned trigger).
    pub fn wait(self) {
        let _ = self.acceptor.join();
        for r in self.runners {
            let _ = r.join();
        }
    }

    /// Stop the daemon and join its threads. In-flight jobs finish their
    /// current scheduler work; queued jobs are abandoned (their spectra —
    /// if any were computed — are already spilled to the disk tier, which
    /// is written through at insert time, so nothing is lost by exiting).
    pub fn shutdown(self) {
        self.shared.stop();
        self.wait();
    }
}

/// Start the daemon: bind the front-end socket, spawn the runner pool and
/// the acceptor, and return immediately with the handle.
pub fn serve(config: DaemonConfig) -> Result<DaemonHandle> {
    let svc = SpectralService::start(config.service.clone())?;
    let listener = TcpListener::bind(&config.addr)
        .with_context(|| format!("binding daemon socket {}", config.addr))?;
    let addr = listener.local_addr().context("resolving bound daemon address")?;
    let quota = config.service.effective_tenant_quota();
    let shared = Arc::new(Shared {
        svc,
        addr,
        queue: Mutex::new(QueueState {
            fair: FairQueue::new(quota, config.effective_quantum()),
            specs: HashMap::new(),
            paused: config.start_paused,
        }),
        queue_cv: Condvar::new(),
        jobs: Mutex::new(HashMap::new()),
        jobs_cv: Condvar::new(),
        next_id: AtomicU64::new(0),
        stopping: AtomicBool::new(false),
        quota_rejections: AtomicU64::new(0),
        request_timeout: config.effective_request_timeout(),
        io_timeout: config.effective_io_timeout(),
    });
    let mut runners = Vec::with_capacity(config.effective_max_inflight());
    for r in 0..config.effective_max_inflight() {
        let sh = Arc::clone(&shared);
        runners.push(
            std::thread::Builder::new()
                .name(format!("lfa-convd-runner-{r}"))
                .spawn(move || runner_loop(&sh))
                .context("spawning daemon runner")?,
        );
    }
    let sh = Arc::clone(&shared);
    let acceptor = std::thread::Builder::new()
        .name("lfa-convd-acceptor".to_string())
        .spawn(move || accept_loop(listener, sh))
        .context("spawning daemon acceptor")?;
    Ok(DaemonHandle { shared, addr, acceptor, runners })
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    for conn in listener.incoming() {
        if shared.stopping.load(Ordering::SeqCst) {
            return;
        }
        if let Ok(stream) = conn {
            let sh = Arc::clone(&shared);
            let _ = std::thread::Builder::new()
                .name("lfa-convd-conn".to_string())
                .spawn(move || handle_connection(stream, &sh));
        }
    }
}

/// Pop the next dispatchable job, blocking on the queue condvar. `None`
/// means the daemon is stopping.
fn next_job(shared: &Shared) -> Option<(u64, String, PendingSpec)> {
    let mut q = shared.lock_queue();
    loop {
        if shared.stopping.load(Ordering::SeqCst) {
            return None;
        }
        if !q.paused {
            if let Some((id, tenant)) = q.fair.pop() {
                let spec = q.specs.remove(&id).expect("spec tracked for every queued job");
                return Some((id, tenant, spec));
            }
        }
        q = shared.queue_cv.wait(q).unwrap_or_else(|e| e.into_inner());
    }
}

fn runner_loop(shared: &Shared) {
    while let Some((id, tenant, spec)) = next_job(shared) {
        // Deadline check at dispatch: a job that expired while queued is
        // cancelled without running (true cancellation — the scheduler
        // never sees it).
        let run = {
            let mut jobs = shared.lock_jobs();
            match jobs.get_mut(&id) {
                Some(e) if matches!(e.phase, JobPhase::Queued) => {
                    if Instant::now() >= e.deadline {
                        e.phase = JobPhase::TimedOut;
                        false
                    } else {
                        e.phase = JobPhase::Running;
                        true
                    }
                }
                // Already lazily timed out by a POLL/WAIT, or unknown.
                _ => false,
            }
        };
        if run {
            let started = Instant::now();
            let outcome: Result<JobSummary> = match spec.request {
                JobRequest::Spectrum(request) => {
                    shared.svc.audit_model_with(&spec.model, request).map(|reports| JobSummary {
                        layers: reports.len(),
                        sigma_max: reports
                            .iter()
                            .map(|r| r.sigma_max)
                            .fold(f64::NEG_INFINITY, f64::max),
                        solved_freqs: reports.iter().map(|r| r.solved_freqs).sum(),
                        cached_layers: reports.iter().filter(|r| r.cached).count(),
                        elapsed_ms: started.elapsed().as_millis(),
                        density: None,
                    })
                }
                JobRequest::Density(req) => {
                    shared.svc.audit_model_density(&spec.model, req).map(|audit| JobSummary {
                        layers: audit.layers.len(),
                        sigma_max: audit
                            .layers
                            .iter()
                            .map(|l| l.density.sigma_max)
                            .fold(f64::NEG_INFINITY, f64::max),
                        // Cache-served layers keep their *original*
                        // solved count inside the stored density; only
                        // layers that actually swept count as solved here.
                        solved_freqs: audit
                            .layers
                            .iter()
                            .filter(|l| !l.cached)
                            .map(|l| l.density.solved_freqs as usize)
                            .sum(),
                        cached_layers: audit.layers.iter().filter(|l| l.cached).count(),
                        elapsed_ms: started.elapsed().as_millis(),
                        density: Some(DensitySummary {
                            bins: req.bins,
                            sample: req.sample.max(1),
                            coverage: audit
                                .layers
                                .iter()
                                .map(|l| l.density.sampled_fraction())
                                .fold(1.0, f64::min),
                            epsilon: audit
                                .layers
                                .iter()
                                .map(|l| l.density.cdf_epsilon())
                                .fold(0.0, f64::max),
                        }),
                    })
                }
            };
            let mut jobs = shared.lock_jobs();
            if let Some(e) = jobs.get_mut(&id) {
                e.phase = match outcome {
                    Ok(summary) => {
                        if Instant::now() >= e.deadline {
                            // Finished past the deadline: the client was
                            // (or will be) told `timeout`; discard the
                            // summary so the reply never flips.
                            JobPhase::TimedOut
                        } else {
                            JobPhase::Done(summary)
                        }
                    }
                    Err(why) => JobPhase::Failed(failure_tail(id, &why)),
                };
            }
        }
        shared.lock_queue().fair.complete(&tenant);
        shared.queue_cv.notify_all();
        shared.jobs_cv.notify_all();
    }
}

enum Reply {
    /// Write the line, keep the connection.
    Line(String),
    /// Write the line, close the connection.
    Close(String),
}

fn handle_connection(stream: TcpStream, shared: &Shared) {
    let _ = stream.set_read_timeout(Some(shared.io_timeout));
    let _ = stream.set_write_timeout(Some(shared.io_timeout));
    let reader = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut reader = BufReader::new(reader);
    let mut writer = stream;
    loop {
        if shared.stopping.load(Ordering::SeqCst) {
            return;
        }
        let mut line = String::new();
        match reader.read_line(&mut line) {
            // Clean disconnect — possibly mid-session; submitted jobs
            // stay pollable from any new connection.
            Ok(0) => return,
            Ok(_) => {}
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                // Slow consumer: typed reply (best effort), then close so
                // the handler thread is never parked on a dead client.
                let _ = writeln!(
                    writer,
                    "ERR slow-consumer no request within {}ms",
                    shared.io_timeout.as_millis()
                );
                return;
            }
            // Client vanished mid-request (reset, abort): just close.
            Err(_) => return,
        }
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if line.starts_with("GET ") || line.starts_with("HEAD ") {
            handle_http(&mut reader, &mut writer, shared, line);
            return;
        }
        match handle_command(shared, line) {
            Reply::Line(s) => {
                if writeln!(writer, "{s}").is_err() {
                    return;
                }
            }
            Reply::Close(s) => {
                let _ = writeln!(writer, "{s}");
                return;
            }
        }
    }
}

fn handle_command(shared: &Shared, line: &str) -> Reply {
    let mut parts = line.split_whitespace();
    let cmd = parts.next().unwrap_or("").to_ascii_uppercase();
    match cmd.as_str() {
        "PING" => Reply::Line("PONG".to_string()),
        "SUBMIT" => {
            let (Some(tenant), Some(model)) = (parts.next(), parts.next()) else {
                return Reply::Line(
                    "ERR bad-request usage: SUBMIT <tenant> <model> \
                     [top-k=K | density=B [density-sample=S]]"
                        .to_string(),
                );
            };
            let mut topk = None;
            let mut density_bins = None;
            let mut density_sample = 1u32;
            for extra in parts {
                if let Some(k) = extra.strip_prefix("top-k=").or_else(|| extra.strip_prefix("topk="))
                {
                    match k.parse::<usize>() {
                        Ok(k) if k > 0 => topk = Some(k),
                        _ => return Reply::Line(format!("ERR bad-request bad top-k {k:?}")),
                    }
                } else if let Some(b) = extra.strip_prefix("density=") {
                    match b.parse::<u32>() {
                        Ok(b) if b > 0 => density_bins = Some(b),
                        _ => return Reply::Line(format!("ERR bad-request bad density {b:?}")),
                    }
                } else if let Some(s) = extra.strip_prefix("density-sample=") {
                    match s.parse::<u32>() {
                        Ok(s) if s > 0 => density_sample = s,
                        _ => {
                            return Reply::Line(format!("ERR bad-request bad density-sample {s:?}"))
                        }
                    }
                } else {
                    return Reply::Line(format!("ERR bad-request unknown option {extra:?}"));
                }
            }
            if density_sample != 1 && density_bins.is_none() {
                return Reply::Line(
                    "ERR bad-request density-sample requires density=B".to_string(),
                );
            }
            let request = match (topk, density_bins) {
                (Some(_), Some(_)) => {
                    return Reply::Line(
                        "ERR bad-request density conflicts with top-k".to_string(),
                    )
                }
                (Some(k), None) => JobRequest::Spectrum(SpectrumRequest::TopK(k)),
                (None, Some(bins)) => {
                    JobRequest::Density(DensityRequest { bins, sample: density_sample })
                }
                (None, None) => JobRequest::Spectrum(SpectrumRequest::Full),
            };
            Reply::Line(submit(shared, tenant, model, request))
        }
        "POLL" | "WAIT" => {
            let id = match parts.next().map(str::parse::<u64>) {
                Some(Ok(id)) => id,
                _ => return Reply::Line(format!("ERR bad-request usage: {cmd} <job-id>")),
            };
            if cmd == "WAIT" {
                Reply::Line(wait_job(shared, id))
            } else {
                Reply::Line(poll_job(shared, id))
            }
        }
        "METRICS" => Reply::Line(metrics_line(shared)),
        "STATS" => Reply::Line(stats_line(shared)),
        "RESUME" => {
            shared.lock_queue().paused = false;
            shared.queue_cv.notify_all();
            Reply::Line("OK resumed".to_string())
        }
        "QUIT" => Reply::Close("BYE".to_string()),
        "SHUTDOWN" => {
            shared.stop();
            Reply::Close("OK shutting-down".to_string())
        }
        _ => Reply::Line(format!("ERR bad-request unknown command {cmd:?}")),
    }
}

/// Resolve a model token: builtin zoo name first, then a TOML config path.
fn resolve_model(token: &str) -> std::result::Result<ModelConfig, String> {
    if let Some(m) = zoo::builtin(token) {
        return Ok(m);
    }
    let path = Path::new(token);
    if path.exists() {
        return ModelConfig::load(path).map_err(|e| format!("loading {token}: {e}"));
    }
    Err(format!(
        "no builtin model or config file {token:?} (builtins: {})",
        zoo::builtin_names().join(", ")
    ))
}

fn submit(shared: &Shared, tenant: &str, model_token: &str, request: JobRequest) -> String {
    let model = match resolve_model(model_token) {
        Ok(m) => m,
        Err(why) => return format!("ERR bad-request {why}"),
    };
    let cost = model.layers.len().max(1);
    let id = shared.next_id.fetch_add(1, Ordering::SeqCst) + 1;
    let deadline = Instant::now() + shared.request_timeout;
    // Register the job *before* it becomes poppable: a runner may pop the
    // instant the queue lock is released, and must find the entry.
    shared.lock_jobs().insert(
        id,
        JobEntry { tenant: tenant.to_string(), deadline, phase: JobPhase::Queued },
    );
    let admitted = {
        let mut q = shared.lock_queue();
        match q.fair.try_enqueue(tenant, id, cost) {
            Ok(()) => {
                q.specs.insert(id, PendingSpec { model, request });
                Ok(())
            }
            Err(e) => Err(e),
        }
    };
    match admitted {
        Ok(()) => {
            shared.queue_cv.notify_all();
            format!("QUEUED id={id} tenant={tenant} cost={cost}")
        }
        Err(q) => {
            shared.lock_jobs().remove(&id);
            shared.quota_rejections.fetch_add(1, Ordering::Relaxed);
            format!("ERR quota tenant={} pending={} limit={}", q.tenant, q.pending, q.quota)
        }
    }
}

fn done_line(id: u64, s: &JobSummary) -> String {
    let mut line = format!(
        "DONE id={id} layers={} sigma_max={:.6e} solved={} cached={} elapsed_ms={}",
        s.layers, s.sigma_max, s.solved_freqs, s.cached_layers, s.elapsed_ms
    );
    if let Some(d) = &s.density {
        use std::fmt::Write as _;
        let _ = write!(
            line,
            " density_bins={} sample={} coverage={:.3} epsilon={:.4}",
            d.bins, d.sample, d.coverage, d.epsilon
        );
    }
    line
}

/// One non-blocking status probe. Expired non-terminal jobs are lazily
/// marked timed out right here, so a `POLL` never reports `PENDING` past
/// the deadline (the runner honors the marking by skipping the job).
fn probe(jobs: &mut HashMap<u64, JobEntry>, id: u64) -> Option<String> {
    let e = match jobs.get_mut(&id) {
        Some(e) => e,
        None => return Some(format!("ERR unknown-job id={id}")),
    };
    match &e.phase {
        JobPhase::Done(s) => Some(done_line(id, s)),
        JobPhase::Failed(tail) => Some(format!("ERR {tail}")),
        JobPhase::TimedOut => Some(format!("ERR timeout id={id}")),
        JobPhase::Queued | JobPhase::Running => {
            if Instant::now() >= e.deadline {
                e.phase = JobPhase::TimedOut;
                Some(format!("ERR timeout id={id}"))
            } else {
                None // non-terminal; poll_job/wait_job decide
            }
        }
    }
}

fn poll_job(shared: &Shared, id: u64) -> String {
    let mut jobs = shared.lock_jobs();
    if let Some(terminal) = probe(&mut jobs, id) {
        return terminal;
    }
    match jobs.get(&id).map(|e| &e.phase) {
        Some(JobPhase::Running) => format!("RUNNING id={id}"),
        _ => format!("PENDING id={id}"),
    }
}

/// Block until the job reaches a terminal phase or its deadline passes.
/// Bounded: the condvar wait re-checks at least every 100 ms and the
/// deadline converts the job to `timeout`, so `WAIT` can never hang.
fn wait_job(shared: &Shared, id: u64) -> String {
    let mut jobs = shared.lock_jobs();
    loop {
        if let Some(terminal) = probe(&mut jobs, id) {
            return terminal;
        }
        let (guard, _) = shared
            .jobs_cv
            .wait_timeout(jobs, Duration::from_millis(100))
            .unwrap_or_else(|e| e.into_inner());
        jobs = guard;
    }
}

/// The metric names + values the daemon exports, shared by the one-line
/// `METRICS` reply and the HTTP `/metrics` body.
fn metric_pairs(shared: &Shared) -> Vec<(&'static str, u64)> {
    let m = shared.svc.metrics();
    let (tenants, queued) = {
        let q = shared.lock_queue();
        (q.fair.tenant_count() as u64, q.fair.queued_total() as u64)
    };
    vec![
        ("jobs_submitted", m.jobs_submitted),
        ("jobs_completed", m.jobs_completed),
        ("jobs_failed", m.jobs_failed),
        ("tiles_completed", m.tiles_completed),
        ("values_computed", m.values_computed),
        ("cache_hits", m.cache_hits),
        ("cache_misses", m.cache_misses),
        ("cache_evictions", m.cache_evictions),
        ("degraded_freqs", m.degraded_freqs),
        ("escalations", m.escalations),
        ("nonfinite_rejections", m.nonfinite_rejections),
        ("disk_hits", m.disk_hits),
        ("disk_misses", m.disk_misses),
        ("disk_spills", m.disk_spills),
        ("disk_corruptions", m.disk_corruptions),
        ("tenants", tenants),
        ("jobs_queued", queued),
        ("quota_rejections", shared.quota_rejections.load(Ordering::Relaxed)),
    ]
}

fn metrics_line(shared: &Shared) -> String {
    let pairs = metric_pairs(shared);
    let body: Vec<String> = pairs.iter().map(|(k, v)| format!("{k}={v}")).collect();
    format!("METRICS {}", body.join(" "))
}

/// The `STATS` reply: the shared cache/disk/density counters, formatted
/// by the same [`report::stats_kv`] the CLI layer uses — one formatter,
/// two front ends.
fn stats_line(shared: &Shared) -> String {
    format!("STATS {}", report::stats_kv(shared.svc.cache_stats()))
}

fn handle_http(
    reader: &mut BufReader<TcpStream>,
    writer: &mut TcpStream,
    shared: &Shared,
    request_line: &str,
) {
    // Drain the (bounded) header block; the body is ignored.
    for _ in 0..64 {
        let mut header = String::new();
        match reader.read_line(&mut header) {
            Ok(0) => break,
            Ok(_) if header.trim().is_empty() => break,
            Ok(_) => continue,
            Err(_) => break,
        }
    }
    let path = request_line.split_whitespace().nth(1).unwrap_or("/");
    let (status, body) = match path {
        "/metrics" => {
            let lines: Vec<String> =
                metric_pairs(shared).iter().map(|(k, v)| format!("lfa_{k} {v}")).collect();
            ("200 OK", format!("{}\n", lines.join("\n")))
        }
        "/healthz" => ("200 OK", "ok\n".to_string()),
        _ => ("404 Not Found", format!("no route {path}\n")),
    };
    let _ = write!(
        writer,
        "HTTP/1.1 {status}\r\nContent-Type: text/plain; charset=utf-8\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
}

/// Parse a `host:port` string early so the CLI can reject it with a typed
/// error before starting workers (TcpListener::bind would too, later and
/// more opaquely).
pub fn parse_addr(addr: &str) -> Result<SocketAddr> {
    use std::net::ToSocketAddrs;
    let mut addrs = addr
        .to_socket_addrs()
        .map_err(|e| err!("cannot resolve bind address {addr:?}: {e}"))?;
    addrs.next().ok_or_else(|| err!("bind address {addr:?} resolves to nothing"))
}

/// Reject non-loopback binds unless explicitly allowed — the protocol is
/// unauthenticated, so listening on a routable interface is almost always
/// a mistake.
pub fn ensure_loopback(addr: &SocketAddr, allow_remote: bool) -> Result<()> {
    if !allow_remote && !addr.ip().is_loopback() {
        bail!(
            "refusing to bind unauthenticated daemon to non-loopback {addr} \
             (pass --allow-remote to override)"
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn drr_alternates_equal_cost_tenants() {
        let mut q = FairQueue::new(8, 1);
        for id in 0..4u64 {
            q.try_enqueue("a", id, 1).unwrap();
        }
        for id in 10..14u64 {
            q.try_enqueue("b", id, 1).unwrap();
        }
        let order: Vec<String> = std::iter::from_fn(|| q.pop().map(|(_, t)| t)).collect();
        assert_eq!(order, ["a", "b", "a", "b", "a", "b", "a", "b"]);
    }

    #[test]
    fn drr_cost_weighting_equalizes_served_cost() {
        // Tenant a submits cost-3 jobs, tenant b cost-1 jobs: over a long
        // run both are served about the same total cost, i.e. b gets ~3×
        // as many jobs through.
        let mut q = FairQueue::new(100, 1);
        for id in 0..20u64 {
            q.try_enqueue("a", id, 3).unwrap();
        }
        for id in 100..160u64 {
            q.try_enqueue("b", id, 1).unwrap();
        }
        let (mut cost_a, mut cost_b) = (0usize, 0usize);
        for _ in 0..40 {
            let (id, t) = q.pop().expect("work queued");
            if t == "a" {
                cost_a += 3;
                assert!(id < 20);
            } else {
                cost_b += 1;
            }
        }
        let diff = cost_a.abs_diff(cost_b);
        assert!(diff <= 4, "served cost should track: a={cost_a} b={cost_b}");
    }

    #[test]
    fn quota_is_per_tenant_and_frees_on_complete() {
        let mut q = FairQueue::new(2, 1);
        q.try_enqueue("a", 1, 1).unwrap();
        q.try_enqueue("a", 2, 1).unwrap();
        let e = q.try_enqueue("a", 3, 1).unwrap_err();
        assert_eq!(e, QuotaExceeded { tenant: "a".to_string(), pending: 2, quota: 2 });
        // Another tenant is unaffected.
        q.try_enqueue("b", 4, 1).unwrap();
        // Popping alone does NOT free quota (the job is now in flight) …
        let (id, t) = q.pop().unwrap();
        assert_eq!((id, t.as_str()), (1, "a"));
        assert!(q.try_enqueue("a", 5, 1).is_err());
        // … completion does.
        q.complete("a");
        q.try_enqueue("a", 5, 1).unwrap();
        assert_eq!(q.pending("a"), 2);
    }

    #[test]
    fn expensive_job_eventually_served() {
        let mut q = FairQueue::new(8, 2);
        q.try_enqueue("big", 1, 9).unwrap(); // cost > quantum: needs 5 rounds
        q.try_enqueue("small", 2, 1).unwrap();
        let mut order = Vec::new();
        while let Some((id, _)) = q.pop() {
            order.push(id);
        }
        assert_eq!(order.len(), 2);
        assert!(order.contains(&1), "expensive job must not starve");
    }

    #[test]
    fn loopback_guard() {
        let local = parse_addr("127.0.0.1:0").unwrap();
        assert!(ensure_loopback(&local, false).is_ok());
        let remote = parse_addr("0.0.0.0:7733").unwrap();
        assert!(ensure_loopback(&remote, false).is_err());
        assert!(ensure_loopback(&remote, true).is_ok());
    }
}
