//! High-level spectral-analysis service: the API the CLI and examples use.
//!
//! Wraps the scheduler + PJRT executor + artifact manifest into a single
//! object that analyzes layers and whole models, verifies results against
//! the Frobenius identity, and reports per-layer spectral summaries.

use super::job::{Backend, JobSpec, ModelJobSpec};
use super::metrics::MetricsSnapshot;
use super::scheduler::{JobResult, Scheduler, SchedulerConfig};
use crate::conv::ConvKernel;
use crate::engine::{DensityRequest, LayerDensity, ModelPlan, SpectrumRequest};
use crate::error::{Error, Result};
use crate::lfa::{self, BlockSolver, Fold, Precision, SpectrumHealth};
use crate::model::config::ModelConfig;
use crate::runtime::{load_manifest, PjrtExecutor};
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Service configuration.
#[derive(Clone)]
pub struct ServiceConfig {
    /// Worker threads (0 = auto = `available_parallelism`).
    pub workers: usize,
    pub backend: Backend,
    pub solver: BlockSolver,
    /// Artifacts directory (None = native only).
    pub artifacts_dir: Option<std::path::PathBuf>,
    /// Verify each spectrum against the Frobenius identity.
    pub verify: bool,
    /// Conjugate-pair frequency folding for native tiles (default
    /// [`Fold::Auto`]; the CLI's `--no-fold` maps to [`Fold::Off`]).
    pub folding: Fold,
    /// Precision tier for native tiles (default [`Precision::F64`]; the
    /// CLI's `--precision {f64,f32,f32-refined}`). PJRT-routed work always
    /// computes in f32 and caches under [`Precision::F32`] keys.
    pub precision: Precision,
    /// Bounded job-queue depth for the scheduler (0 = default —
    /// [`SchedulerConfig::DEFAULT_QUEUE_DEPTH`]).
    pub queue_depth: usize,
    /// Result/plan cache budget: `None` disables caching, `Some(0)` uses
    /// the default budget, `Some(n)` caps result entries at `n` bytes
    /// (the CLI's `--no-cache` / `--cache-bytes N`). See
    /// [`SchedulerConfig::cache_bytes`].
    pub cache_bytes: Option<usize>,
    /// Directory for the persistent disk cache tier (the CLI's
    /// `--disk-cache-dir`): computed spectra spill to checksummed files
    /// and are read back across process restarts. `None` (the default)
    /// keeps the cache memory-only. Requires caching to be enabled —
    /// [`Self::validate`] rejects a disk dir with `cache_bytes: None`.
    pub disk_cache_dir: Option<std::path::PathBuf>,
    /// Per-tenant admission quota for the daemon front-end: the maximum
    /// number of jobs one tenant may have queued + running at once before
    /// submissions are rejected with a typed backpressure reply (0 = the
    /// default, [`Self::DEFAULT_TENANT_QUOTA`]). Unused by the in-process
    /// API — only `serve` enforces it.
    pub tenant_quota: usize,
    /// Strict numerical-health mode (the CLI's `--strict-health`). By
    /// default a spectrum still degraded after the escalation ladder is
    /// *served flagged* — [`LayerReport::health`] carries the evidence and
    /// the result is refused by the cache. Under strict mode the same
    /// outcome becomes a typed job error
    /// ([`crate::ErrorKind::DegradedSpectrum`]) instead of a report.
    pub strict_health: bool,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            workers: 0,
            backend: Backend::Auto,
            solver: BlockSolver::Jacobi,
            artifacts_dir: None,
            verify: true,
            folding: Fold::Auto,
            precision: Precision::F64,
            queue_depth: 0,
            cache_bytes: Some(0),
            disk_cache_dir: None,
            tenant_quota: 0,
            strict_health: false,
        }
    }
}

impl ServiceConfig {
    /// Default per-tenant admission quota (`tenant_quota == 0`).
    pub const DEFAULT_TENANT_QUOTA: usize = 8;

    /// Resolve the `0 = default` tenant-quota convention.
    pub fn effective_tenant_quota(&self) -> usize {
        if self.tenant_quota == 0 {
            Self::DEFAULT_TENANT_QUOTA
        } else {
            self.tenant_quota
        }
    }

    /// Validate cross-field consistency. [`SpectralService::start`] calls
    /// this, so an inconsistent config fails fast instead of silently
    /// dropping a tier.
    pub fn validate(&self) -> Result<()> {
        if self.disk_cache_dir.is_some() && self.cache_bytes.is_none() {
            crate::bail!(
                "disk_cache_dir requires caching: the disk tier sits below the \
                 in-memory result cache (drop --no-cache or the disk dir)"
            );
        }
        if let Some(dir) = &self.disk_cache_dir {
            if dir.exists() && !dir.is_dir() {
                crate::bail!("disk_cache_dir {} exists and is not a directory", dir.display());
            }
        }
        Ok(())
    }
}

/// Per-layer analysis report.
pub struct LayerReport {
    pub name: String,
    pub n: usize,
    pub m: usize,
    /// Output channels of the *audited operator* — the adjoint's (swapped)
    /// shape for transposed layers, total channels for grouped ones.
    pub c_out: usize,
    /// Input channels of the audited operator (total, not per-group).
    pub c_in: usize,
    pub num_values: usize,
    pub sigma_max: f64,
    /// NaN under a partial (top-k) request — the retained extremes don't
    /// span the operator's smallest value (see [`lfa::Spectrum::sigma_min`]).
    pub sigma_min: f64,
    /// NaN under a partial (top-k) request, like [`Self::sigma_min`].
    pub condition: f64,
    pub elapsed: Duration,
    pub pjrt_tiles: usize,
    pub native_tiles: usize,
    /// Block SVDs actually performed for this layer: the folded
    /// fundamental domain for folded native execution, the full grid for
    /// PJRT/unfolded, 0 when served from the result cache — the per-layer
    /// term of the `frequencies solved:` report line.
    pub solved_freqs: usize,
    /// Whether this layer came straight from the result cache.
    pub cached: bool,
    /// Relative Frobenius-identity defect (NaN when verification is off).
    pub frobenius_defect: f64,
    /// Convergence certificate aggregated over every frequency solved for
    /// this layer. `health.is_degraded()` means the escalation ladder was
    /// exhausted and the values for those frequencies carry no certificate
    /// — the report ships flagged (or, under
    /// [`ServiceConfig::strict_health`], never ships at all).
    pub health: SpectrumHealth,
    /// Shared with the scheduler's result cache on cached/cacheable paths.
    pub spectrum: Arc<lfa::Spectrum>,
}

/// Whole-model density audit ([`SpectralService::audit_model_density`]):
/// per-layer streaming singular-value histograms in model order, plus the
/// wall-clock of the whole sweep.
pub struct DensityAudit {
    /// Per-layer densities (shared with the result cache on cached runs).
    pub layers: Vec<LayerDensity>,
    /// Wall-clock for the whole audit (planning + sweeps + cache traffic).
    pub elapsed: Duration,
}

/// The spectral-analysis service.
pub struct SpectralService {
    scheduler: Scheduler,
    config: ServiceConfig,
}

impl SpectralService {
    /// Start the service. Loads the artifact manifest and spawns the PJRT
    /// executor when an artifacts directory is configured; falls back to
    /// native-only (with a warning) when PJRT cannot start — including when
    /// the crate was built without the `pjrt` feature, whose stub executor
    /// always fails to spawn.
    pub fn start(config: ServiceConfig) -> Result<Self> {
        config.validate()?;
        let (artifacts, executor) = match &config.artifacts_dir {
            Some(dir) if dir.join("manifest.txt").exists() => {
                let specs = load_manifest(dir)?;
                match PjrtExecutor::spawn() {
                    Ok(exec) => (specs, Some(exec)),
                    Err(e) => {
                        eprintln!("warning: PJRT unavailable ({e}); native only");
                        (Vec::new(), None)
                    }
                }
            }
            Some(dir) => {
                eprintln!(
                    "warning: no manifest at {}; run `make artifacts`. native only",
                    dir.display()
                );
                (Vec::new(), None)
            }
            None => (Vec::new(), None),
        };
        let scheduler = Scheduler::start(
            SchedulerConfig {
                workers: config.workers,
                queue_depth: config.queue_depth,
                artifacts,
                cache_bytes: config.cache_bytes,
                disk_cache_dir: config.disk_cache_dir.clone(),
            },
            executor,
        );
        Ok(Self { scheduler, config })
    }

    /// Native-only service with `workers` threads.
    pub fn native(workers: usize) -> Self {
        Self {
            scheduler: Scheduler::native(workers),
            config: ServiceConfig { workers, ..Default::default() },
        }
    }

    /// Analyze a single layer.
    pub fn analyze_layer(
        &self,
        name: &str,
        kernel: &ConvKernel,
        n: usize,
        m: usize,
    ) -> Result<LayerReport> {
        let spec = JobSpec::new(name, kernel.clone(), n, m)
            .with_backend(self.config.backend)
            .with_solver(self.config.solver)
            .with_folding(self.config.folding)
            .with_precision(self.config.precision);
        let result = self.scheduler.run(spec)?;
        let report = self.report(name, kernel, n, m, result);
        self.enforce_health(&report)?;
        Ok(report)
    }

    /// Analyze every conv layer of a model config (weights materialized
    /// from the config's seed — the paper's "random weight tensors").
    ///
    /// The whole model is submitted as **one planned job**: the scheduler
    /// builds a single [`crate::engine::ModelPlan`] (equal-shape layers
    /// share workspace pools) and executes per-layer tiles against it —
    /// no per-layer plan lookups. Per-layer `elapsed` is summed tile work,
    /// not wall-clock, since tiles of different layers interleave.
    pub fn audit_model(&self, model: &ModelConfig) -> Result<Vec<LayerReport>> {
        self.audit_model_with(model, SpectrumRequest::Full)
    }

    /// [`Self::audit_model`] with an explicit [`SpectrumRequest`]:
    /// `TopK(k)` audits compute only the `k` extreme singular values per
    /// frequency (warm-started Krylov iteration per tile strip) — the
    /// fast mode when the report's consumers only need σ extrema and the
    /// Lipschitz bound. Frobenius verification is skipped for partial
    /// spectra (the identity needs the whole spectrum), so
    /// `frobenius_defect` comes back NaN — and so do `sigma_min` and
    /// `condition`, because the retained per-frequency values are the
    /// *largest* ones and say nothing about the small end.
    pub fn audit_model_with(
        &self,
        model: &ModelConfig,
        request: SpectrumRequest,
    ) -> Result<Vec<LayerReport>> {
        let spec = ModelJobSpec::new(&model.name, model.clone())
            .with_backend(self.config.backend)
            .with_solver(self.config.solver)
            .with_folding(self.config.folding)
            .with_precision(self.config.precision)
            .with_request(request);
        let result = self.scheduler.run_model(spec)?;
        let mut reports = Vec::with_capacity(result.layers.len());
        for (layer, outcome) in model.layers.iter().zip(result.layers) {
            let kernel = layer.materialize(model.seed);
            reports.push(self.layer_report(
                outcome.name,
                &kernel,
                layer.height,
                layer.width,
                layer.stride,
                outcome.spectrum,
                outcome.elapsed,
                outcome.pjrt_tiles,
                outcome.native_tiles,
                outcome.solved_freqs,
                outcome.cached,
            ));
        }
        for report in &reports {
            self.enforce_health(report)?;
        }
        Ok(reports)
    }

    /// Streaming **spectral-density** audit of every conv layer: instead
    /// of assembling `freqs × rank` singular values per layer, each layer
    /// runs the two-pass density pipeline
    /// ([`crate::engine::SpectralPlan::density_with`]) — an exact top-1
    /// sweep for σ_max, then histogram accumulation over the (optionally
    /// sub-sampled, `req.sample`) dual grid — and ships `req.bins`
    /// counters with coverage error bars. Densities are served from and
    /// populate the scheduler's result cache exactly like spectra
    /// (content-addressed, shared byte budget, degraded results refused),
    /// so a repeat density audit of an unchanged model solves zero
    /// frequencies. The [`ServiceConfig::strict_health`] gate applies
    /// unchanged: a layer still degraded after the escalation ladder is a
    /// typed error under strict mode, a flagged report otherwise.
    pub fn audit_model_density(
        &self,
        model: &ModelConfig,
        req: DensityRequest,
    ) -> Result<DensityAudit> {
        let started = Instant::now();
        // Density sweeps thread *inside* each layer's plan (pass 1 strip
        // partitioning + pass 2 per-worker sinks) rather than through the
        // scheduler's tile queue, so the plan carries the worker budget.
        let opts = lfa::LfaOptions {
            solver: self.config.solver,
            folding: self.config.folding,
            threads: self.config.workers,
            precision: self.config.precision,
            ..Default::default()
        };
        let plan = match self.scheduler.cache() {
            Some(c) => ModelPlan::build_cached(model, opts, c),
            None => ModelPlan::build(model, opts),
        }
        .map_err(|e| e.context(format!("planning density audit of model {}", model.name)))?;
        let layers = match self.scheduler.cache() {
            Some(c) => plan.density_all_cached(req, c),
            None => plan.density_all(req),
        };
        if self.config.strict_health {
            for l in &layers {
                if l.density.is_degraded() {
                    return Err(Error::degraded_spectrum(
                        &l.name,
                        l.density.health.degraded_freqs as usize,
                    ));
                }
            }
        }
        Ok(DensityAudit { layers, elapsed: started.elapsed() })
    }

    fn report(
        &self,
        name: &str,
        kernel: &ConvKernel,
        n: usize,
        m: usize,
        result: JobResult,
    ) -> LayerReport {
        self.layer_report(
            name.to_string(),
            kernel,
            n,
            m,
            1,
            result.spectrum,
            result.elapsed,
            result.pjrt_tiles,
            result.native_tiles,
            result.solved_freqs,
            result.cached,
        )
    }

    /// Shared [`LayerReport`] assembly for the per-layer and whole-model
    /// paths. `n`/`m` are the fine input grid; `stride` selects the right
    /// Frobenius identity (`frobenius_check` is the stride-1 special case).
    fn layer_report(
        &self,
        name: String,
        kernel: &ConvKernel,
        n: usize,
        m: usize,
        stride: usize,
        spectrum: Arc<lfa::Spectrum>,
        elapsed: Duration,
        pjrt_tiles: usize,
        native_tiles: usize,
        solved_freqs: usize,
        cached: bool,
    ) -> LayerReport {
        // The Frobenius identity sums *every* σ², so it can only verify
        // full spectra; partial (top-k) spectra report NaN.
        let defect = if self.config.verify && spectrum.is_full() {
            lfa::svd::frobenius_check_strided(kernel, n, m, stride, &spectrum)
        } else {
            f64::NAN
        };
        LayerReport {
            name,
            n,
            m,
            // Operator channel dims (grouped kernels store the per-group
            // input width; a transposed audit reports the adjoint's shape).
            c_out: if kernel.transposed { kernel.c_in_total() } else { kernel.c_out },
            c_in: if kernel.transposed { kernel.c_out } else { kernel.c_in_total() },
            num_values: spectrum.num_values(),
            sigma_max: spectrum.sigma_max(),
            // NaN under a top-k request: Spectrum's partial-spectrum guard
            // (the fix for reporting extremes off truncated spectra).
            sigma_min: spectrum.sigma_min(),
            condition: spectrum.condition_number(),
            elapsed,
            pjrt_tiles,
            native_tiles,
            solved_freqs,
            cached,
            frobenius_defect: defect,
            health: spectrum.health,
            spectrum,
        }
    }

    /// Strict-health gate: a degraded report becomes a typed error
    /// ([`crate::ErrorKind::DegradedSpectrum`]) instead of shipping
    /// flagged. No-op unless [`ServiceConfig::strict_health`] is set.
    fn enforce_health(&self, report: &LayerReport) -> Result<()> {
        if self.config.strict_health && report.health.is_degraded() {
            return Err(Error::degraded_spectrum(
                &report.name,
                report.health.degraded_freqs as usize,
            ));
        }
        Ok(())
    }

    /// Point-in-time metrics, with the disk-tier counters merged in from
    /// the cache (the scheduler's `Metrics` atomics are compute-side only;
    /// the cache owns disk traffic). This is what the daemon's `/metrics`
    /// endpoint renders — the report layer cannot silently drop the tier.
    pub fn metrics(&self) -> MetricsSnapshot {
        let mut snap = self.scheduler.metrics.snapshot();
        if let Some(stats) = self.cache_stats() {
            snap.disk_hits = stats.disk_hits;
            snap.disk_misses = stats.disk_misses;
            snap.disk_spills = stats.disk_spills;
            snap.disk_corruptions = stats.disk_corruptions;
        }
        snap
    }

    /// The service's configuration (as resolved at start).
    pub fn config(&self) -> &ServiceConfig {
        &self.config
    }

    /// Stats of the scheduler's result/plan cache (None when caching is
    /// disabled via [`ServiceConfig::cache_bytes`]).
    pub fn cache_stats(&self) -> Option<crate::engine::CacheStats> {
        self.scheduler.cache().map(|c| c.stats())
    }

    /// The resolved bounded job-queue depth the scheduler runs with.
    pub fn queue_depth(&self) -> usize {
        self.scheduler.queue_depth()
    }

    pub fn shutdown(self) {
        self.scheduler.shutdown();
    }

    /// Helper used by examples: discover the default artifacts directory
    /// relative to the crate root.
    pub fn default_artifacts_dir() -> std::path::PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }
}

/// Convenience free function mirroring the paper's Algorithm 1 entry point.
pub fn analyze(kernel: &ConvKernel, n: usize, m: usize, workers: usize) -> Result<LayerReport> {
    let svc = SpectralService::native(workers);
    let rep = svc.analyze_layer("layer", kernel, n, m)?;
    svc.shutdown();
    Ok(rep)
}
