//! Job and tile descriptions for the spectral-analysis coordinator.

use crate::conv::ConvKernel;
use crate::engine::SpectrumRequest;
use crate::lfa::{BlockSolver, Fold, Precision};
use crate::model::config::ModelConfig;
use std::sync::Arc;

/// Which backend executes the per-tile work.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Native rust LFA pipeline (symbol + Jacobi per block).
    Native,
    /// AOT-compiled JAX/Pallas artifact via PJRT.
    Pjrt,
    /// Prefer PJRT when an artifact matches the layer shape, else native.
    Auto,
}

/// A spectral-analysis job: one convolution layer on an `n×m` grid.
#[derive(Clone)]
pub struct JobSpec {
    /// Stable identifier for reporting.
    pub id: String,
    pub kernel: Arc<ConvKernel>,
    pub n: usize,
    pub m: usize,
    pub solver: BlockSolver,
    pub backend: Backend,
    /// Conjugate-pair frequency folding for native tiles (default
    /// [`Fold::Auto`]): the job's plan solves only the fundamental domain
    /// of `θ → −θ`, tiles cover its rows, and assembly mirrors the rest.
    /// PJRT-routed jobs always sweep the full grid.
    pub folding: Fold,
    /// Precision tier for native tiles (default [`Precision::F64`]).
    /// PJRT artifacts always compute in f32 — their results cache under a
    /// key pinned to [`Precision::F32`] regardless of this field.
    pub precision: Precision,
    /// Frequency rows per tile (0 = pick automatically).
    pub tile_rows: usize,
}

impl JobSpec {
    pub fn new(id: impl Into<String>, kernel: ConvKernel, n: usize, m: usize) -> Self {
        Self {
            id: id.into(),
            kernel: Arc::new(kernel),
            n,
            m,
            solver: BlockSolver::Jacobi,
            backend: Backend::Auto,
            folding: Fold::Auto,
            precision: Precision::F64,
            tile_rows: 0,
        }
    }

    pub fn with_backend(mut self, backend: Backend) -> Self {
        self.backend = backend;
        self
    }

    pub fn with_solver(mut self, solver: BlockSolver) -> Self {
        self.solver = solver;
        self
    }

    pub fn with_folding(mut self, folding: Fold) -> Self {
        self.folding = folding;
        self
    }

    pub fn with_precision(mut self, precision: Precision) -> Self {
        self.precision = precision;
        self
    }

    pub fn with_tile_rows(mut self, rows: usize) -> Self {
        self.tile_rows = rows;
        self
    }

    /// Values per frequency. Grouped kernels store the per-group input
    /// width, so the block-diagonal rank is `min(c_out, c_in_total)` —
    /// identical to a dense kernel of the same total shape (transposition
    /// is rank-preserving, dilation shape-preserving).
    pub fn rank(&self) -> usize {
        self.kernel.c_out.min(self.kernel.c_in_total())
    }

    /// Total singular values of the full grid.
    pub fn total_values(&self) -> usize {
        self.n * self.m * self.rank()
    }

    /// Tile size heuristic for tiling `rows` frequency rows — the full
    /// grid, or the folded fundamental domain (`≈ n/2` rows) when the
    /// job's plan folds: aim for ≥ 8 tiles per worker for load balance
    /// while keeping tiles ≥ 1 row.
    pub fn effective_tile_rows(&self, rows: usize, workers: usize) -> usize {
        if self.tile_rows > 0 {
            return self.tile_rows.min(rows).max(1);
        }
        let target_tiles = (workers * 8).max(1);
        rows.div_ceil(target_tiles).max(1)
    }
}

/// A whole-model spectral-analysis job: every conv layer of a model,
/// planned once as a single [`crate::engine::ModelPlan`] at submission and
/// executed as tiles against the shared plan — no per-layer plan lookups.
#[derive(Clone)]
pub struct ModelJobSpec {
    /// Stable identifier for reporting.
    pub id: String,
    pub model: ModelConfig,
    pub solver: BlockSolver,
    pub backend: Backend,
    /// How much of each layer's spectrum to compute. `TopK(k)` tiles run
    /// the warm-started top-k sweep natively — under `Backend::Auto` the
    /// PJRT artifact routing is simply skipped (artifacts bake the full
    /// per-frequency SVD in), while an explicit `Backend::Pjrt` combined
    /// with a top-k request is rejected at submission.
    pub request: SpectrumRequest,
    /// Conjugate-pair frequency folding for native tiles (default
    /// [`Fold::Auto`]); per-layer PJRT-routed tiles always sweep the full
    /// grid.
    pub folding: Fold,
    /// Precision tier for native tiles (default [`Precision::F64`]).
    /// PJRT-routed layers compute in f32 regardless and cache under keys
    /// pinned to [`Precision::F32`].
    pub precision: Precision,
    /// Coarse frequency rows per tile (0 = pick automatically per layer).
    pub tile_rows: usize,
}

impl ModelJobSpec {
    pub fn new(id: impl Into<String>, model: ModelConfig) -> Self {
        Self {
            id: id.into(),
            model,
            solver: BlockSolver::Jacobi,
            backend: Backend::Auto,
            request: SpectrumRequest::Full,
            folding: Fold::Auto,
            precision: Precision::F64,
            tile_rows: 0,
        }
    }

    pub fn with_backend(mut self, backend: Backend) -> Self {
        self.backend = backend;
        self
    }

    pub fn with_solver(mut self, solver: BlockSolver) -> Self {
        self.solver = solver;
        self
    }

    pub fn with_folding(mut self, folding: Fold) -> Self {
        self.folding = folding;
        self
    }

    pub fn with_precision(mut self, precision: Precision) -> Self {
        self.precision = precision;
        self
    }

    pub fn with_request(mut self, request: SpectrumRequest) -> Self {
        self.request = request;
        self
    }

    pub fn with_tile_rows(mut self, rows: usize) -> Self {
        self.tile_rows = rows;
        self
    }

    /// Tile size for a layer with `coarse_rows` frequency rows: the
    /// explicit override, else enough tiles for load balance without
    /// flooding the queue (models already fan out across layers).
    pub fn effective_tile_rows(&self, coarse_rows: usize, workers: usize) -> usize {
        if self.tile_rows > 0 {
            return self.tile_rows.min(coarse_rows).max(1);
        }
        let target_tiles = (workers * 4).max(1);
        coarse_rows.div_ceil(target_tiles).max(1)
    }
}

/// One unit of scheduled work: frequency rows `[row_lo, row_hi)` of a job.
#[derive(Clone)]
pub struct Tile {
    pub job: Arc<JobSpec>,
    pub row_lo: usize,
    pub row_hi: usize,
}

impl Tile {
    pub fn num_values(&self) -> usize {
        (self.row_hi - self.row_lo) * self.job.m * self.job.rank()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numeric::Pcg64;

    fn job(n: usize) -> JobSpec {
        let mut rng = Pcg64::seeded(1);
        JobSpec::new("t", ConvKernel::random_he(4, 3, 3, 3, &mut rng), n, n)
    }

    #[test]
    fn totals() {
        let j = job(8);
        assert_eq!(j.rank(), 3);
        assert_eq!(j.total_values(), 8 * 8 * 3);
    }

    #[test]
    fn tile_heuristic_bounds() {
        let j = job(64);
        let t = j.effective_tile_rows(64, 4);
        assert!(t >= 1 && t <= 64);
        assert!(64usize.div_ceil(t) >= 16, "enough tiles for 4 workers");
        // The folded fundamental domain sizes tiles from its own row count.
        let tf = j.effective_tile_rows(33, 4);
        assert!(33usize.div_ceil(tf) >= 16, "enough folded tiles for 4 workers");
        // explicit override wins (clamped to the tiled rows).
        let j2 = job(64).with_tile_rows(5);
        assert_eq!(j2.effective_tile_rows(64, 4), 5);
        assert_eq!(j2.effective_tile_rows(3, 4), 3);
    }

    #[test]
    fn tiny_grids_get_one_row_tiles() {
        let j = job(2);
        assert!(j.effective_tile_rows(2, 16) >= 1);
    }

    #[test]
    fn model_job_tile_heuristic() {
        let model = crate::model::ModelConfig { name: "m".into(), seed: 0, layers: vec![] };
        let spec = ModelJobSpec::new("m", model.clone());
        // 32 coarse rows, 4 workers → 2-row tiles (16 tiles).
        assert_eq!(spec.effective_tile_rows(32, 4), 2);
        assert_eq!(spec.effective_tile_rows(1, 16), 1);
        // Explicit override wins, clamped to the grid.
        let spec2 = ModelJobSpec::new("m", model).with_tile_rows(64);
        assert_eq!(spec2.effective_tile_rows(8, 4), 8);
    }
}
