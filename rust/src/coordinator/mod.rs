//! L3 coordinator: frequency-tile scheduling across a worker pool, with
//! native and PJRT backends, metrics, and the high-level
//! [`SpectralService`] API. This is the system expression of the paper's
//! "embarrassingly parallel" remark (§V): tiles of the dual grid are
//! independent, so the spectrum of a layer scales out trivially — and a
//! whole model, submitted as one planned [`crate::engine::ModelPlan`]
//! object ([`Scheduler::submit_model`]), scales out across every layer's
//! tiles at once.

pub mod job;
pub mod metrics;
pub mod scheduler;
#[cfg(feature = "daemon")]
pub mod server;
pub mod service;

pub use job::{Backend, JobSpec, ModelJobSpec, Tile};
pub use metrics::{Metrics, MetricsSnapshot};
pub use scheduler::{JobResult, LayerOutcome, ModelJobResult, Scheduler, SchedulerConfig};
#[cfg(feature = "daemon")]
pub use server::{serve, DaemonConfig, DaemonHandle, FairQueue, QuotaExceeded};
pub use service::{analyze, DensityAudit, LayerReport, ServiceConfig, SpectralService};
