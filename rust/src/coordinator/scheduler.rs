//! Frequency-tile scheduler: the L3 realization of the paper's closing
//! observation — *"unlike the FFT, the LFA is embarrassingly parallel."*
//!
//! A job's `n×m` frequency grid is cut into row tiles; a pool of worker
//! threads pulls tiles from a shared queue (work stealing by construction),
//! computes each tile's singular values — natively or through the PJRT
//! executor — and writes them into the job's result buffer. A bounded
//! submission channel provides backpressure when jobs arrive faster than
//! workers drain them.
//!
//! Every job carries one shared [`SpectralPlan`]: phase tables are computed
//! once at submission and every native tile executes against the plan's
//! pooled workspaces, so a job no longer rebuilds symbol state per tile.
//! When the plan folds (conjugate-pair frequency folding,
//! `lfa::Fold::Auto` — the default), tiles cover only the fundamental
//! domain of `θ → −θ` (about half the rows) and assembly mirrors the
//! conjugate half at completion — the same ~2× SVD-work cut the direct
//! engine paths get, bit-identical to them.
//!
//! Whole models go further: [`Scheduler::submit_model`] plans *all* layers
//! once as a single [`ModelPlan`] (equal-shape layers share workspace
//! pools) and queues per-layer row tiles against that one planned object —
//! there is no per-layer plan lookup or rebuild anywhere in the model path.
//!
//! Repeat traffic short-circuits even earlier: a [`SpectralCache`]
//! (enabled by default, [`SchedulerConfig::cache_bytes`]) is consulted
//! **before tiling** — a job (or model layer) whose content signature
//! matches a cached result is served the shared spectrum with zero tiles
//! queued and zero frequencies re-solved, and freshly computed results
//! populate the cache at job finish. Signatures pin the precision tier,
//! so this covers every execution route: native jobs key at their
//! requested [`Precision`], and PJRT-routed work — whose AOT artifacts
//! compute in f32 — keys at [`Precision::F32`], interchangeable with a
//! native f32 sweep of the same content and with nothing else. Plans are
//! cached the same way, so a repeat submission re-plans nothing.
//! Model jobs carry a [`SpectrumRequest`]: `TopK(k)` tiles run the
//! warm-started top-k sweep over their contiguous row strip natively (AOT
//! artifacts bake in the full per-frequency SVD, so `Backend::Auto` skips
//! artifact routing and an explicit `Backend::Pjrt` is rejected at
//! submission) and the result stitches into per-layer *partial* spectra.

use super::job::{Backend, JobSpec, ModelJobSpec, Tile};
use super::metrics::Metrics;
use crate::engine::{
    resolve_threads, ModelPlan, Signature, SpectralCache, SpectralPlan, SpectrumRequest,
};
use crate::engine::DiskCache;
use crate::err;
use crate::error::{Error, ErrorKind, Result};
use crate::lfa::{self, LfaOptions, Precision, SpectrumHealth};
use crate::runtime::{ArtifactSpec, PjrtExecutor};
use crate::testing::chaos;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Scheduler configuration.
#[derive(Clone)]
pub struct SchedulerConfig {
    /// Worker threads for native tiles (0 = auto = `available_parallelism`).
    pub workers: usize,
    /// Bounded queue depth for submitted jobs (backpressure);
    /// 0 = the default depth ([`SchedulerConfig::DEFAULT_QUEUE_DEPTH`]).
    pub queue_depth: usize,
    /// Artifact manifest (empty = native only).
    pub artifacts: Vec<ArtifactSpec>,
    /// Result/plan cache byte budget: `None` disables caching, `Some(0)`
    /// uses [`crate::engine::DEFAULT_CACHE_BYTES`], `Some(n)` caps result
    /// entries at `n` bytes. Every execution route is served from (and
    /// populates) the cache: signatures pin the precision tier, so
    /// PJRT-routed work caches under [`Precision::F32`] keys and can never
    /// be served where an f64 (or refined) spectrum was requested. The one
    /// uncacheable shape is an explicit-PJRT job with no matching artifact,
    /// which contractually fails instead of computing.
    pub cache_bytes: Option<usize>,
    /// Directory for the persistent disk tier below the in-memory LRU
    /// ([`crate::engine::DiskCache`]): computed spectra are written
    /// through to checksummed spill files and read back across process
    /// restarts. `None` (the default) keeps the cache memory-only;
    /// ignored when caching is disabled (`cache_bytes: None`). If the
    /// directory cannot be created the scheduler degrades to memory-only
    /// with a warning rather than refusing to start.
    pub disk_cache_dir: Option<PathBuf>,
}

impl SchedulerConfig {
    /// Default bounded submission-queue depth.
    pub const DEFAULT_QUEUE_DEPTH: usize = 16;

    /// Resolve the `0 = default` queue-depth convention.
    pub fn effective_queue_depth(&self) -> usize {
        if self.queue_depth == 0 {
            Self::DEFAULT_QUEUE_DEPTH
        } else {
            self.queue_depth
        }
    }
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        Self {
            workers: 0,
            queue_depth: 0,
            artifacts: Vec::new(),
            cache_bytes: Some(0),
            disk_cache_dir: None,
        }
    }
}

/// Result of one job.
pub struct JobResult {
    pub id: String,
    /// The spectrum — shared with the scheduler's result cache, so a
    /// cache-served job hands back the very buffer a previous job computed.
    pub spectrum: Arc<lfa::Spectrum>,
    /// Wall-clock for the whole job.
    pub elapsed: std::time::Duration,
    /// Tiles executed via PJRT / natively.
    pub pjrt_tiles: usize,
    pub native_tiles: usize,
    /// Block SVDs this job actually performed: the folded fundamental
    /// domain for folded native jobs, the full grid for PJRT/unfolded
    /// ones, 0 when served from cache.
    pub solved_freqs: usize,
    /// Whether the result came straight from the cache.
    pub cached: bool,
}

/// Per-layer outcome of a whole-model job.
pub struct LayerOutcome {
    pub name: String,
    /// Shared with the scheduler's result cache (see [`JobResult`]).
    pub spectrum: Arc<lfa::Spectrum>,
    /// Summed tile work for this layer (not wall-clock — tiles of different
    /// layers interleave across the pool).
    pub elapsed: Duration,
    pub pjrt_tiles: usize,
    pub native_tiles: usize,
    /// Block SVDs actually performed for this layer (0 on a cache hit —
    /// the per-layer term of the truthful `frequencies solved:` line).
    pub solved_freqs: usize,
    /// Whether this layer was served from the result cache.
    pub cached: bool,
}

/// Result of one whole-model job: per-layer outcomes in model order.
pub struct ModelJobResult {
    pub id: String,
    pub layers: Vec<LayerOutcome>,
    /// Wall-clock for the whole model.
    pub elapsed: Duration,
    pub pjrt_tiles: usize,
    pub native_tiles: usize,
}

struct JobState {
    spec: Arc<JobSpec>,
    /// Planned symbol→SVD state shared by every tile of this job.
    /// `None` for jobs routed entirely to a PJRT artifact (no native tiles).
    plan: Option<Arc<SpectralPlan>>,
    values: Mutex<Vec<f64>>,
    /// Merged solver-certificate evidence across this job's native tiles
    /// (PJRT tiles carry none — the artifact boundary strips certificates).
    health: Mutex<SpectrumHealth>,
    remaining: AtomicUsize,
    pjrt_tiles: AtomicUsize,
    native_tiles: AtomicUsize,
    started: Instant,
    done_tx: mpsc::Sender<Result<JobResult>>,
    /// Artifact chosen for this job (None = native).
    artifact: Option<ArtifactSpec>,
    /// Pre-converted f32 weights for the PJRT path.
    weights_f32: Vec<f32>,
    /// Result cache to populate at finish, with the job's content
    /// signature — precision-pinned to `F32` for PJRT-routed jobs. `None`
    /// when caching is off or the job contractually fails (explicit PJRT
    /// without an artifact).
    cache: Option<(Arc<SpectralCache>, Signature)>,
}

/// Per-layer tile bookkeeping for a whole-model job.
struct LayerCounters {
    pjrt: AtomicUsize,
    native: AtomicUsize,
    work_nanos: AtomicU64,
}

struct ModelJobState {
    spec: Arc<ModelJobSpec>,
    /// All layers, planned once at submission; tiles only execute.
    plan: Arc<ModelPlan>,
    /// Per-layer values-per-frequency under the job's request (equals the
    /// layer rank for `Full`, `min(k, rank)` for top-k).
    values_per_freq: Vec<usize>,
    /// Per-layer start offsets in the flat buffer (group-major execution
    /// order, matching `ModelPlan::spectra_from_flat_request`).
    offsets: Vec<usize>,
    /// Flat whole-model values buffer (per-layer offsets above).
    values: Mutex<Vec<f64>>,
    /// Per-layer merged certificate evidence from native tiles (empty for
    /// PJRT-routed and cache-hit layers).
    layer_health: Mutex<Vec<SpectrumHealth>>,
    remaining: AtomicUsize,
    layer_counters: Vec<LayerCounters>,
    started: Instant,
    done_tx: mpsc::Sender<Result<ModelJobResult>>,
    /// Set by the first failing tile so the whole model job is accounted
    /// failed exactly once (`jobs_failed += layer count`, balancing the
    /// per-layer `jobs_submitted` accounting).
    failed: AtomicBool,
    /// Per-layer artifact routing (None = native).
    artifacts: Vec<Option<ArtifactSpec>>,
    /// Pre-converted f32 weights for PJRT-routed layers (empty otherwise).
    weights_f32: Vec<Vec<f32>>,
    /// Result cache + per-layer signatures (precision-pinned to `F32` for
    /// PJRT-routed layers, `None` only for contractually failing ones) and
    /// the per-layer cache hits: a hit layer has no tiles — its spectrum
    /// ships straight from here at finish.
    cache: Option<Arc<SpectralCache>>,
    keys: Vec<Option<Signature>>,
    cached: Vec<Option<Arc<lfa::Spectrum>>>,
}

enum Work {
    Tile { state: Arc<JobState>, tile: Tile },
    ModelTile { state: Arc<ModelJobState>, layer: usize, row_lo: usize, row_hi: usize },
    Shutdown,
}

/// The tile scheduler & worker pool.
pub struct Scheduler {
    work_tx: mpsc::SyncSender<Work>,
    workers: Vec<std::thread::JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    config: SchedulerConfig,
    executor: Option<PjrtExecutor>,
    /// Content-addressed result & plan cache (None when disabled).
    cache: Option<Arc<SpectralCache>>,
}

impl Scheduler {
    /// Start the pool. If `executor` is `Some`, jobs whose shape matches an
    /// artifact may run on PJRT (per their backend policy).
    pub fn start(config: SchedulerConfig, executor: Option<PjrtExecutor>) -> Self {
        let mut config = config;
        config.workers = resolve_threads(config.workers);
        let cache = config.cache_bytes.map(|b| {
            let mut cache = SpectralCache::with_budget_or_default(b);
            if let Some(dir) = &config.disk_cache_dir {
                match DiskCache::open(dir) {
                    Ok(disk) => cache = cache.with_disk(disk),
                    Err(e) => eprintln!(
                        "warning: disk cache tier disabled (falling back to memory-only): {e}"
                    ),
                }
            }
            Arc::new(cache)
        });
        let (work_tx, work_rx) =
            mpsc::sync_channel::<Work>(config.effective_queue_depth().max(1) * 4);
        let work_rx = Arc::new(Mutex::new(work_rx));
        let metrics = Arc::new(Metrics::default());
        let mut workers = Vec::with_capacity(config.workers);
        for w in 0..config.workers.max(1) {
            let rx = Arc::clone(&work_rx);
            let metrics = Arc::clone(&metrics);
            let executor = executor.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("lfa-worker-{w}"))
                    .spawn(move || worker_loop(rx, metrics, executor))
                    .expect("spawning worker"),
            );
        }
        Self { work_tx, workers, metrics, config, executor, cache }
    }

    /// Convenience: native-only scheduler (`workers == 0` = auto).
    pub fn native(workers: usize) -> Self {
        Self::start(SchedulerConfig { workers, ..Default::default() }, None)
    }

    /// The scheduler's result/plan cache (None when disabled via
    /// [`SchedulerConfig::cache_bytes`]).
    pub fn cache(&self) -> Option<&Arc<SpectralCache>> {
        self.cache.as_ref()
    }

    /// The resolved bounded-queue depth jobs are submitted against.
    pub fn queue_depth(&self) -> usize {
        self.config.effective_queue_depth()
    }

    /// Submit a job; returns a receiver for its result. Blocks (backpressure)
    /// if the work queue is full. The job's [`SpectralPlan`] is built here,
    /// once — tiles only execute.
    pub fn submit(&self, spec: JobSpec) -> mpsc::Receiver<Result<JobResult>> {
        let (done_tx, done_rx) = mpsc::channel();
        // Non-finite screen, before *any* accounting, planning, or tiling:
        // a NaN/Inf weight tensor is rejected with a typed error and leaves
        // `jobs_submitted` untouched (the acceptance contract of the
        // numerical-health layer).
        let bad = spec.kernel.non_finite_count();
        if bad > 0 {
            self.metrics.nonfinite_rejections.fetch_add(1, Ordering::Relaxed);
            let _ = done_tx.send(Err(Error::non_finite_weights(&spec.id, bad)));
            return done_rx;
        }
        self.metrics.jobs_submitted.fetch_add(1, Ordering::Relaxed);
        let spec = Arc::new(spec);
        let artifact = self.pick_artifact(&spec);
        let opts = LfaOptions {
            solver: spec.solver,
            folding: spec.folding,
            threads: 1,
            precision: spec.precision,
            ..Default::default()
        };
        // Cache check before any tiling or planning. Signatures pin the
        // precision tier, so every route that computes is cacheable:
        // artifact-routed jobs key at `Precision::F32` (that is what PJRT
        // delivers, whatever the spec asked for) and native jobs key at
        // their requested tier. The one exception: an explicit-PJRT job
        // without an artifact contractually *fails*, so it must not be
        // silently served from a cached result either.
        let cache = if artifact.is_some() || spec.backend != Backend::Pjrt {
            self.cache.as_ref().map(|c| {
                let key = Signature::result(
                    &spec.kernel,
                    spec.n,
                    spec.m,
                    1,
                    &opts,
                    SpectrumRequest::Full,
                );
                let key =
                    if artifact.is_some() { key.with_precision(Precision::F32) } else { key };
                (Arc::clone(c), key)
            })
        } else {
            None
        };
        if let Some((c, key)) = &cache {
            if let Some(spectrum) = c.get(key) {
                // Served entirely from cache: zero tiles, zero frequencies
                // re-solved; the job still counts submitted + completed.
                self.metrics.cache_hits.fetch_add(1, Ordering::Relaxed);
                self.metrics.jobs_completed.fetch_add(1, Ordering::Relaxed);
                let _ = done_tx.send(Ok(JobResult {
                    id: spec.id.clone(),
                    spectrum,
                    elapsed: Duration::ZERO,
                    pjrt_tiles: 0,
                    native_tiles: 0,
                    solved_freqs: 0,
                    cached: true,
                }));
                return done_rx;
            }
            self.metrics.cache_misses.fetch_add(1, Ordering::Relaxed);
        }
        let weights_f32 = if artifact.is_some() {
            spec.kernel.data.iter().map(|&v| v as f32).collect()
        } else {
            Vec::new()
        };
        // Jobs with a matching artifact run every tile on PJRT and never
        // touch the native path — skip the planning cost for them. Native
        // jobs draw their plan from the plan cache when one is running:
        // equal plan signatures share phase tables and warmed workspaces.
        // The plan key derives from the result key computed above, so one
        // submission hashes the weight tensor exactly once.
        let plan = if artifact.is_none() {
            Some(match (&self.cache, &cache) {
                (Some(c), Some((_, key))) => {
                    let pkey = key.for_plan(opts.threads);
                    match c.plan_lookup(&pkey) {
                        Some(p) => p,
                        None => c.plan_store(
                            pkey,
                            Arc::new(SpectralPlan::new(&spec.kernel, spec.n, spec.m, opts)),
                        ),
                    }
                }
                // The cache tuple is None (with a live cache) only for
                // explicit-PJRT jobs without an artifact — they
                // contractually fail in the worker, so don't let them
                // churn warmed plans out of the capped plan cache.
                (Some(_), None) | (None, _) => {
                    Arc::new(SpectralPlan::new(&spec.kernel, spec.n, spec.m, opts))
                }
            })
        } else {
            None
        };
        // Native folded jobs tile only the fundamental-domain rows of the
        // conjugate involution θ → −θ; finish_job mirrors the rest.
        // Artifact jobs always sweep the full grid.
        let tiled_rows = match &plan {
            Some(p) if p.folded() => p.solved_rows(),
            _ => spec.n,
        };
        let tile_rows = match &artifact {
            Some(a) => a.tile_rows,
            None => spec.effective_tile_rows(tiled_rows, self.config.workers),
        };
        let tiles: Vec<(usize, usize)> = {
            let mut v = Vec::new();
            let mut lo = 0;
            while lo < tiled_rows {
                v.push((lo, (lo + tile_rows).min(tiled_rows)));
                lo += tile_rows;
            }
            v
        };
        let state = Arc::new(JobState {
            spec: Arc::clone(&spec),
            plan,
            values: Mutex::new(vec![0.0; spec.total_values()]),
            health: Mutex::new(SpectrumHealth::default()),
            remaining: AtomicUsize::new(tiles.len()),
            pjrt_tiles: AtomicUsize::new(0),
            native_tiles: AtomicUsize::new(0),
            started: Instant::now(),
            done_tx,
            artifact,
            weights_f32,
            cache,
        });
        for (lo, hi) in tiles {
            self.metrics.tiles_dispatched.fetch_add(1, Ordering::Relaxed);
            let tile = Tile { job: Arc::clone(&spec), row_lo: lo, row_hi: hi };
            // SyncSender blocks when full — this is the backpressure point.
            self.work_tx
                .send(Work::Tile { state: Arc::clone(&state), tile })
                .expect("worker pool is gone");
        }
        done_rx
    }

    /// Submit and wait.
    pub fn run(&self, spec: JobSpec) -> Result<JobResult> {
        let rx = self.submit(spec);
        rx.recv().map_err(|_| err!("job dropped without a result"))?
    }

    /// Submit a whole model as **one planned object**: a [`ModelPlan`] is
    /// built here, once — every layer's phase tables, equal-shape groups
    /// sharing workspace pools — and per-layer row tiles are queued against
    /// it. Layers whose shape matches an AOT artifact route to PJRT (per
    /// the backend policy); everything else executes natively against the
    /// shared plan. Metrics count one job per layer, so model audits and
    /// per-layer audits report comparably.
    pub fn submit_model(&self, spec: ModelJobSpec) -> mpsc::Receiver<Result<ModelJobResult>> {
        let (done_tx, done_rx) = mpsc::channel();
        let nlayers = spec.model.layers.len();
        // An *explicit* PJRT backend cannot serve a partial-spectrum
        // request (AOT artifacts bake in the full per-frequency SVD) —
        // fail loudly instead of silently downgrading to native.
        // `Backend::Auto` + top-k routes native by design.
        if spec.backend == Backend::Pjrt && spec.request != SpectrumRequest::Full {
            self.metrics.jobs_submitted.fetch_add(nlayers as u64, Ordering::Relaxed);
            self.metrics.jobs_failed.fetch_add(nlayers as u64, Ordering::Relaxed);
            let _ = done_tx.send(Err(err!(
                "model job {}: PJRT cannot serve partial-spectrum (top-k) requests — \
                 the AOT artifacts bake in the full per-frequency SVD; use \
                 Backend::Auto or Backend::Native",
                spec.id
            )));
            return done_rx;
        }
        let opts = LfaOptions {
            solver: spec.solver,
            folding: spec.folding,
            threads: 1,
            precision: spec.precision,
            ..Default::default()
        };
        // The plan cache makes a repeat model submission re-plan nothing:
        // every layer's plan signature matches and the planned objects
        // (phase tables + warmed pools) are shared. Building also runs the
        // non-finite weight screen — a rejected model leaves
        // `jobs_submitted` untouched (nothing was accepted; the typed
        // error reaches the caller before any frequency is solved), so the
        // accepted-work accounting only happens once the plan exists.
        let built = match &self.cache {
            Some(c) => ModelPlan::build_cached(&spec.model, opts, c),
            None => ModelPlan::build(&spec.model, opts),
        };
        let plan = match built {
            Ok(p) => {
                self.metrics.jobs_submitted.fetch_add(nlayers as u64, Ordering::Relaxed);
                Arc::new(p)
            }
            Err(e) => {
                if matches!(e.kind(), ErrorKind::NonFiniteWeights { .. }) {
                    self.metrics.nonfinite_rejections.fetch_add(1, Ordering::Relaxed);
                } else {
                    self.metrics.jobs_submitted.fetch_add(nlayers as u64, Ordering::Relaxed);
                    self.metrics.jobs_failed.fetch_add(nlayers as u64, Ordering::Relaxed);
                }
                // Inherent `Error::context` preserves the typed kind, so
                // the daemon can still map this to `ERR nonfinite`.
                let _ = done_tx.send(Err(e.context(format!("planning model job {}", spec.id))));
                return done_rx;
            }
        };
        // Per-layer artifact routing: stride-1 layers whose shape matches.
        // Top-k jobs always run natively — the AOT artifacts bake the full
        // per-frequency SVD in, so PJRT cannot serve a partial request.
        let mut artifacts: Vec<Option<ArtifactSpec>> = Vec::with_capacity(nlayers);
        let mut weights_f32: Vec<Vec<f32>> = Vec::with_capacity(nlayers);
        for i in 0..nlayers {
            let lp = plan.layer_plan(i);
            let art = if self.executor.is_some()
                && spec.backend != Backend::Native
                && spec.request == SpectrumRequest::Full
                && lp.stride() == 1
                && lp.kernel().is_dense()
            {
                let k = lp.kernel();
                crate::runtime::select(
                    &self.config.artifacts,
                    lp.coarse_rows(),
                    lp.coarse_cols(),
                    k.c_out,
                    k.c_in,
                    k.kh,
                    k.kw,
                    true,
                )
                .cloned()
            } else {
                None
            };
            let w = if art.is_some() {
                lp.kernel().data.iter().map(|&v| v as f32).collect()
            } else {
                Vec::new()
            };
            artifacts.push(art);
            weights_f32.push(w);
        }
        // Result-cache check, per layer: a layer whose signature hits gets
        // **no tiles** — its spectrum ships from the cache at finish, zero
        // frequencies re-solved. Native layers key at the job's precision
        // tier; PJRT-routed layers key at `Precision::F32` (what the AOT
        // artifact computes in), so a repeat PJRT audit is a pure hit and
        // an f32 result can never be served to an f64 consumer.
        let mut keys: Vec<Option<Signature>> = vec![None; nlayers];
        let mut cached: Vec<Option<Arc<lfa::Spectrum>>> = vec![None; nlayers];
        if let Some(c) = &self.cache {
            for i in 0..nlayers {
                // (Explicit-PJRT model jobs fail per unmatched layer —
                // never mask that with a cached result.)
                if artifacts[i].is_some() || spec.backend != Backend::Pjrt {
                    // Cached builds stored each layer's plan signature:
                    // derive the result key instead of re-hashing the
                    // whole weight tensor a second time per submission.
                    let key = match plan.layer_plan_signature(i) {
                        Some(ps) => ps.for_request(spec.request),
                        None => plan.layer_plan(i).result_signature(spec.request),
                    };
                    let key = if artifacts[i].is_some() {
                        key.with_precision(Precision::F32)
                    } else {
                        key
                    };
                    cached[i] = c.get(&key);
                    if cached[i].is_some() {
                        self.metrics.cache_hits.fetch_add(1, Ordering::Relaxed);
                    } else {
                        self.metrics.cache_misses.fetch_add(1, Ordering::Relaxed);
                    }
                    keys[i] = Some(key);
                }
            }
        }
        // Tiles: per-layer row ranges against the shared plan. Native
        // tiles of a folded layer cover only its fundamental-domain rows
        // (finish_model_job mirrors the conjugate halves); PJRT-routed
        // layers always sweep the full grid; cache-hit layers get none.
        let mut tiles: Vec<(usize, usize, usize)> = Vec::new();
        for i in 0..nlayers {
            if cached[i].is_some() {
                continue;
            }
            let lp = plan.layer_plan(i);
            let nrows = if artifacts[i].is_none() && lp.folded() {
                lp.solved_rows()
            } else {
                lp.coarse_rows()
            };
            let tr = match &artifacts[i] {
                Some(a) => a.tile_rows,
                None => spec.effective_tile_rows(nrows, self.config.workers),
            };
            let mut lo = 0usize;
            while lo < nrows {
                tiles.push((i, lo, (lo + tr).min(nrows)));
                lo += tr;
            }
        }
        // Per-layer buffer geometry under the request. Offsets come from
        // the plan itself — the same single source of truth
        // `spectra_from_flat_request` slices by — so tile placement and
        // result slicing cannot drift apart.
        let values_per_freq: Vec<usize> = (0..nlayers)
            .map(|i| spec.request.values_per_freq(plan.layer_plan(i).rank()))
            .collect();
        let offsets = plan.request_offsets(spec.request);
        let total_values = plan.request_values_len(spec.request);
        // Every layer a cache hit ⇒ no tiles ⇒ the whole-model buffer is
        // never touched: don't allocate (and zero) it on the pure-lookup
        // path — that allocation is exactly what a hit is meant to skip.
        let values = if tiles.is_empty() { Vec::new() } else { vec![0.0; total_values] };
        let spec = Arc::new(spec);
        let state = Arc::new(ModelJobState {
            spec: Arc::clone(&spec),
            values_per_freq,
            offsets,
            values: Mutex::new(values),
            layer_health: Mutex::new(vec![SpectrumHealth::default(); nlayers]),
            remaining: AtomicUsize::new(tiles.len()),
            layer_counters: (0..nlayers)
                .map(|_| LayerCounters {
                    pjrt: AtomicUsize::new(0),
                    native: AtomicUsize::new(0),
                    work_nanos: AtomicU64::new(0),
                })
                .collect(),
            started: Instant::now(),
            done_tx,
            failed: AtomicBool::new(false),
            artifacts,
            weights_f32,
            plan,
            cache: self.cache.clone(),
            keys,
            cached,
        });
        if state.remaining.load(Ordering::Relaxed) == 0 {
            // Every layer hit the cache: nothing to schedule, finish now.
            finish_model_job(&state, &self.metrics);
            return done_rx;
        }
        for (layer, lo, hi) in tiles {
            self.metrics.tiles_dispatched.fetch_add(1, Ordering::Relaxed);
            // SyncSender blocks when full — the same backpressure point as
            // per-layer jobs.
            self.work_tx
                .send(Work::ModelTile { state: Arc::clone(&state), layer, row_lo: lo, row_hi: hi })
                .expect("worker pool is gone");
        }
        done_rx
    }

    /// Submit a whole model and wait.
    pub fn run_model(&self, spec: ModelJobSpec) -> Result<ModelJobResult> {
        let rx = self.submit_model(spec);
        rx.recv().map_err(|_| err!("model job dropped without a result"))?
    }

    fn pick_artifact(&self, spec: &JobSpec) -> Option<ArtifactSpec> {
        // Structured kernels (grouped / dilated / transposed) never match
        // an AOT artifact — the compiled program bakes dense forward
        // geometry in.
        if self.executor.is_none() || spec.backend == Backend::Native || !spec.kernel.is_dense() {
            return None;
        }
        let k = &spec.kernel;
        crate::runtime::select(
            &self.config.artifacts,
            spec.n,
            spec.m,
            k.c_out,
            k.c_in,
            k.kh,
            k.kw,
            true,
        )
        .cloned()
        // Explicit PJRT requested but no artifact: the job fails in the
        // worker; keeping submit() infallible.
    }

    /// Graceful shutdown: waits for queued work to finish.
    pub fn shutdown(self) {
        for _ in &self.workers {
            let _ = self.work_tx.send(Work::Shutdown);
        }
        drop(self.work_tx);
        for w in self.workers {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    rx: Arc<Mutex<mpsc::Receiver<Work>>>,
    metrics: Arc<Metrics>,
    executor: Option<PjrtExecutor>,
) {
    loop {
        let work = {
            let guard = rx.lock().expect("queue poisoned");
            guard.recv()
        };
        match work {
            Ok(Work::Tile { state, tile }) => {
                let t0 = Instant::now();
                // A panicking tile (solver bug, chaos injection) must fail
                // its *job* with a typed error, not silently kill this
                // worker thread and hang the submitter forever.
                let outcome =
                    catch_unwind(AssertUnwindSafe(|| run_tile(&state, &tile, executor.as_ref())))
                        .unwrap_or_else(|payload| {
                            Err(err!(
                                "job {}: worker panicked mid-tile (rows {}..{}): {}",
                                state.spec.id,
                                tile.row_lo,
                                tile.row_hi,
                                panic_message(payload.as_ref())
                            ))
                        });
                let used_pjrt = matches!(outcome, Ok(true));
                match outcome {
                    Ok(_) => {
                        metrics.record_tile(tile.num_values(), t0.elapsed(), used_pjrt);
                        if used_pjrt {
                            state.pjrt_tiles.fetch_add(1, Ordering::Relaxed);
                        } else {
                            state.native_tiles.fetch_add(1, Ordering::Relaxed);
                        }
                        if state.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                            finish_job(&state, &metrics);
                        }
                    }
                    Err(e) => {
                        metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
                        let _ = state.done_tx.send(Err(e));
                    }
                }
            }
            Ok(Work::ModelTile { state, layer, row_lo, row_hi }) => {
                let t0 = Instant::now();
                let outcome = catch_unwind(AssertUnwindSafe(|| {
                    run_model_tile(&state, layer, row_lo, row_hi, executor.as_ref())
                }))
                .unwrap_or_else(|payload| {
                    Err(err!(
                        "model job {}: worker panicked mid-tile (layer {:?}, rows {}..{}): {}",
                        state.spec.id,
                        state.plan.layer_name(layer),
                        row_lo,
                        row_hi,
                        panic_message(payload.as_ref())
                    ))
                });
                match outcome {
                    Ok(used_pjrt) => {
                        let lp = state.plan.layer_plan(layer);
                        let vals =
                            (row_hi - row_lo) * lp.coarse_cols() * state.values_per_freq[layer];
                        let elapsed = t0.elapsed();
                        metrics.record_tile(vals, elapsed, used_pjrt);
                        let counters = &state.layer_counters[layer];
                        counters
                            .work_nanos
                            .fetch_add(elapsed.as_nanos() as u64, Ordering::Relaxed);
                        if used_pjrt {
                            counters.pjrt.fetch_add(1, Ordering::Relaxed);
                        } else {
                            counters.native.fetch_add(1, Ordering::Relaxed);
                        }
                        if state.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                            finish_model_job(&state, &metrics);
                        }
                    }
                    Err(e) => {
                        // Account the whole model job failed exactly once
                        // (it was submitted as one job per layer), no
                        // matter how many of its tiles error.
                        if !state.failed.swap(true, Ordering::Relaxed) {
                            let nlayers = state.spec.model.layers.len() as u64;
                            metrics.jobs_failed.fetch_add(nlayers, Ordering::Relaxed);
                        }
                        let _ = state.done_tx.send(Err(e));
                    }
                }
            }
            Ok(Work::Shutdown) | Err(_) => return,
        }
    }
}

/// Sweep a PJRT artifact over rows `[row_lo, row_hi)`. The artifact
/// computes `art.tile_rows` rows per call; the last call may overshoot the
/// range and its surplus values are trimmed. `row_vals` is the number of
/// singular values per frequency row (`cols · rank`). Shared by the
/// per-layer and whole-model tile paths so the partial-tile slicing cannot
/// diverge between them.
fn pjrt_tile_values(
    exec: &PjrtExecutor,
    art: &ArtifactSpec,
    weights: &[f32],
    row_lo: usize,
    row_hi: usize,
    row_vals: usize,
) -> Result<Vec<f64>> {
    let mut vals = Vec::with_capacity((row_hi - row_lo) * row_vals);
    let mut row = row_lo;
    while row < row_hi {
        let reply = exec.run_tile(art, weights, row as i32)?;
        let take = (row_hi - row).min(art.tile_rows) * row_vals;
        vals.extend(reply.values[..take].iter().map(|&v| v as f64));
        row += art.tile_rows;
    }
    Ok(vals)
}

/// Stringify a caught panic payload for the typed job error.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Execute one tile. Returns Ok(true) if it ran via PJRT.
fn run_tile(state: &JobState, tile: &Tile, executor: Option<&PjrtExecutor>) -> Result<bool> {
    let spec = &state.spec;
    // Fault-injection points for the chaos suite (free when disarmed).
    if chaos::fire(chaos::TILE_PANIC) {
        panic!("chaos: injected tile panic (job {})", spec.id);
    }
    if chaos::fire(chaos::TILE_ERROR) {
        return Err(err!("job {}: chaos: injected tile failure", spec.id));
    }
    let r = spec.rank();
    let (values, health, used_pjrt): (Vec<f64>, SpectrumHealth, bool) =
        match (&state.artifact, executor) {
            (Some(art), Some(exec)) => {
                let vals = pjrt_tile_values(
                    exec,
                    art,
                    &state.weights_f32,
                    tile.row_lo,
                    tile.row_hi,
                    spec.m * r,
                )?;
                // No certificates cross the PJRT boundary — empty evidence.
                (vals, SpectrumHealth::default(), true)
            }
            _ => {
                if state.artifact.is_none() && spec.backend == Backend::Pjrt {
                    return Err(err!(
                        "job {}: PJRT backend requested but no artifact matches \
                         (n={}, c_out={}, c_in={}); run `make artifacts` or use Backend::Auto",
                        spec.id,
                        spec.n,
                        spec.kernel.c_out,
                        spec.kernel.c_in
                    ));
                }
                // Native path: execute against the job's shared plan. Workspace
                // checkout reuses the buffers of whichever worker last ran a
                // tile of this job — no per-tile symbol state rebuild. Folded
                // plans solve their tile's fundamental-domain rows only (the
                // unified row driver dispatches on the plan's fold mode).
                let plan = state.plan.as_ref().expect("native jobs always carry a plan");
                let mut vals = vec![0.0f64; tile.num_values()];
                let (_, h) = plan.execute_request_rows_pooled(
                    SpectrumRequest::Full,
                    tile.row_lo,
                    tile.row_hi,
                    &mut vals,
                );
                (vals, h, false)
            }
        };
    state.health.lock().unwrap_or_else(|e| e.into_inner()).merge(&health);
    let base = tile.row_lo * spec.m * r;
    // Poison-tolerant: a tile that panicked while holding this lock has
    // already failed its job (catch_unwind → typed error); later tiles of
    // *other* jobs must keep working, not cascade the panic.
    let mut buf = state.values.lock().unwrap_or_else(|e| e.into_inner());
    buf[base..base + values.len()].copy_from_slice(&values);
    Ok(used_pjrt)
}

/// Execute one tile of a whole-model job. Returns Ok(true) if it ran via
/// PJRT.
fn run_model_tile(
    state: &ModelJobState,
    layer: usize,
    row_lo: usize,
    row_hi: usize,
    executor: Option<&PjrtExecutor>,
) -> Result<bool> {
    // Fault-injection points for the chaos suite (free when disarmed).
    if chaos::fire(chaos::TILE_PANIC) {
        panic!("chaos: injected tile panic (model job {})", state.spec.id);
    }
    if chaos::fire(chaos::TILE_ERROR) {
        return Err(err!("model job {}: chaos: injected tile failure", state.spec.id));
    }
    let lp = state.plan.layer_plan(layer);
    let r = state.values_per_freq[layer];
    let mc = lp.coarse_cols();
    let artifact = &state.artifacts[layer];
    let (values, health, used_pjrt): (Vec<f64>, SpectrumHealth, bool) = match (artifact, executor)
    {
        (Some(art), Some(exec)) => {
            let vals = pjrt_tile_values(
                exec,
                art,
                &state.weights_f32[layer],
                row_lo,
                row_hi,
                mc * r,
            )?;
            // No certificates cross the PJRT boundary — empty evidence.
            (vals, SpectrumHealth::default(), true)
        }
        _ => {
            // (Pjrt + top-k is rejected at submission, so this error path
            // only concerns full-spectrum jobs.)
            if state.artifacts[layer].is_none() && state.spec.backend == Backend::Pjrt {
                let k = lp.kernel();
                return Err(err!(
                    "model job {}: PJRT backend requested but no artifact matches layer \
                     {:?} (n={}, c_out={}, c_in={}); run `make artifacts` or use Backend::Auto",
                    state.spec.id,
                    state.plan.layer_name(layer),
                    lp.coarse_rows(),
                    k.c_out,
                    k.c_in
                ));
            }
            // Native path: execute against the layer's plan inside the
            // shared ModelPlan. Workspace checkout goes to the layer
            // *group's* pool, so equal-shape layers reuse each other's
            // scratch across the whole model. Top-k tiles run the
            // warm-started top-k sweep over their contiguous row strip
            // (cold at the strip's first frequency, warm along it).
            // Folded layers' tiles cover fundamental-domain rows only —
            // the unified row driver dispatches on request and fold mode.
            let mut vals = vec![0.0f64; (row_hi - row_lo) * mc * r];
            let (_, h) =
                lp.execute_request_rows_pooled(state.spec.request, row_lo, row_hi, &mut vals);
            (vals, h, false)
        }
    };
    state.layer_health.lock().unwrap_or_else(|e| e.into_inner())[layer].merge(&health);
    let base = state.offsets[layer] + row_lo * mc * r;
    // Poison-tolerant: a tile that panicked while holding this lock has
    // already failed its job (catch_unwind → typed error); later tiles of
    // *other* jobs must keep working, not cascade the panic.
    let mut buf = state.values.lock().unwrap_or_else(|e| e.into_inner());
    buf[base..base + values.len()].copy_from_slice(&values);
    Ok(used_pjrt)
}

fn finish_model_job(state: &ModelJobState, metrics: &Metrics) {
    let mut values = std::mem::take(&mut *state.values.lock().unwrap_or_else(|e| e.into_inner()));
    let layer_health =
        std::mem::take(&mut *state.layer_health.lock().unwrap_or_else(|e| e.into_inner()));
    // Mirror the conjugate halves of folded native layers in, and account
    // the mirrored values as delivered (matching the per-layer job path).
    // Cache-hit layers were never tiled: their values ship from the cache
    // below and count nothing as computed.
    for i in 0..state.plan.layer_count() {
        let lp = state.plan.layer_plan(i);
        if state.cached[i].is_none() && state.artifacts[i].is_none() && lp.folded() {
            let r = state.values_per_freq[i];
            let off = state.offsets[i];
            let len = lp.freqs() * r;
            lfa::spectrum::mirror_fill(
                lp.coarse_rows(),
                lp.coarse_cols(),
                r,
                &mut values[off..off + len],
            );
            let mirrored = (lp.coarse_rows() - lp.solved_rows()) * lp.coarse_cols() * r;
            metrics.values_computed.fetch_add(mirrored as u64, Ordering::Relaxed);
        }
    }
    let mut layers = Vec::with_capacity(state.plan.layer_count());
    let mut pjrt_total = 0usize;
    let mut native_total = 0usize;
    for i in 0..state.plan.layer_count() {
        let lp = state.plan.layer_plan(i);
        let c = &state.layer_counters[i];
        let pjrt = c.pjrt.load(Ordering::Relaxed);
        let native = c.native.load(Ordering::Relaxed);
        pjrt_total += pjrt;
        native_total += native;
        // Folded/unfolded/PJRT/cached accounted separately: solved_freqs
        // is what this layer's tiles actually decomposed.
        let (spectrum, solved, cached) = match &state.cached[i] {
            Some(sp) => (Arc::clone(sp), 0usize, true),
            None => {
                let r = state.values_per_freq[i];
                let off = state.offsets[i];
                let slice = values[off..off + lp.freqs() * r].to_vec();
                let health = layer_health[i];
                metrics.degraded_freqs.fetch_add(health.degraded_freqs, Ordering::Relaxed);
                metrics.lfa_escalations.fetch_add(health.escalations, Ordering::Relaxed);
                let spectrum =
                    Arc::new(lp.spectrum_from_values_health(state.spec.request, slice, health));
                // Freshly computed layers enter the result cache under
                // their precision-pinned key (F32 for PJRT-routed ones).
                // The cache's admission gate refuses a spectrum still
                // flagged degraded — it ships to the caller flagged, once,
                // but is never replayable.
                if let (Some(cache), Some(key)) = (&state.cache, &state.keys[i]) {
                    let evicted = cache.insert(*key, Arc::clone(&spectrum));
                    metrics.cache_evictions.fetch_add(evicted, Ordering::Relaxed);
                }
                let solved = if state.artifacts[i].is_none() {
                    lp.solved_freqs()
                } else {
                    lp.freqs()
                };
                (spectrum, solved, false)
            }
        };
        layers.push(LayerOutcome {
            name: state.plan.layer_name(i).to_string(),
            spectrum,
            elapsed: Duration::from_nanos(c.work_nanos.load(Ordering::Relaxed)),
            pjrt_tiles: pjrt,
            native_tiles: native,
            solved_freqs: solved,
            cached,
        });
    }
    metrics.jobs_completed.fetch_add(layers.len() as u64, Ordering::Relaxed);
    let _ = state.done_tx.send(Ok(ModelJobResult {
        id: state.spec.id.clone(),
        layers,
        elapsed: state.started.elapsed(),
        pjrt_tiles: pjrt_total,
        native_tiles: native_total,
    }));
}

fn finish_job(state: &JobState, metrics: &Metrics) {
    let spec = &state.spec;
    let mut values = std::mem::take(&mut *state.values.lock().unwrap_or_else(|e| e.into_inner()));
    if let Some(plan) = state.plan.as_ref() {
        if plan.folded() {
            // The tiles covered the fundamental domain of θ → −θ; mirror
            // the conjugate half in and account the mirrored values as
            // delivered (values_computed counts what the job produced).
            lfa::spectrum::mirror_fill(spec.n, spec.m, spec.rank(), &mut values);
            let mirrored = (spec.n - plan.solved_rows()) * spec.m * spec.rank();
            metrics.values_computed.fetch_add(mirrored as u64, Ordering::Relaxed);
        }
    }
    // Operator dimensions, not kernel storage: grouped kernels store the
    // per-group input width, and a transposed audit reports the adjoint's
    // (swapped) shape.
    let (sym_rows, sym_cols) = if spec.kernel.transposed {
        (spec.kernel.c_in_total(), spec.kernel.c_out)
    } else {
        (spec.kernel.c_out, spec.kernel.c_in_total())
    };
    let health = *state.health.lock().unwrap_or_else(|e| e.into_inner());
    metrics.degraded_freqs.fetch_add(health.degraded_freqs, Ordering::Relaxed);
    metrics.lfa_escalations.fetch_add(health.escalations, Ordering::Relaxed);
    let spectrum = Arc::new(lfa::Spectrum {
        n: spec.n,
        m: spec.m,
        c_out: sym_rows,
        c_in: sym_cols,
        per_freq: spec.rank(),
        values,
        health,
    });
    // Freshly computed results populate the cache for repeats, under the
    // precision-pinned key (F32 for PJRT-routed jobs). The cache's
    // admission gate refuses a spectrum still flagged degraded — it ships
    // to the caller flagged, once, but is never replayable.
    if let Some((cache, key)) = &state.cache {
        let evicted = cache.insert(*key, Arc::clone(&spectrum));
        metrics.cache_evictions.fetch_add(evicted, Ordering::Relaxed);
    }
    let solved_freqs = match state.plan.as_ref() {
        Some(plan) => plan.solved_freqs(),
        None => spec.n * spec.m,
    };
    metrics.jobs_completed.fetch_add(1, Ordering::Relaxed);
    let _ = state.done_tx.send(Ok(JobResult {
        id: spec.id.clone(),
        spectrum,
        elapsed: state.started.elapsed(),
        pjrt_tiles: state.pjrt_tiles.load(Ordering::Relaxed),
        native_tiles: state.native_tiles.load(Ordering::Relaxed),
        solved_freqs,
        cached: false,
    }));
}
