//! Frequency-tile scheduler: the L3 realization of the paper's closing
//! observation — *"unlike the FFT, the LFA is embarrassingly parallel."*
//!
//! A job's `n×m` frequency grid is cut into row tiles; a pool of worker
//! threads pulls tiles from a shared queue (work stealing by construction),
//! computes each tile's singular values — natively or through the PJRT
//! executor — and writes them into the job's result buffer. A bounded
//! submission channel provides backpressure when jobs arrive faster than
//! workers drain them.
//!
//! Every job carries one shared [`SpectralPlan`]: phase tables are computed
//! once at submission and every native tile executes against the plan's
//! pooled workspaces, so a job no longer rebuilds symbol state per tile.

use super::job::{Backend, JobSpec, Tile};
use super::metrics::Metrics;
use crate::engine::{resolve_threads, SpectralPlan};
use crate::err;
use crate::error::Result;
use crate::lfa::{self, LfaOptions};
use crate::runtime::{ArtifactSpec, PjrtExecutor};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

/// Scheduler configuration.
#[derive(Clone)]
pub struct SchedulerConfig {
    /// Worker threads for native tiles (0 = auto = `available_parallelism`).
    pub workers: usize,
    /// Bounded queue depth for submitted jobs (backpressure).
    pub queue_depth: usize,
    /// Artifact manifest (empty = native only).
    pub artifacts: Vec<ArtifactSpec>,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        Self { workers: 0, queue_depth: 16, artifacts: Vec::new() }
    }
}

/// Result of one job.
pub struct JobResult {
    pub id: String,
    pub spectrum: lfa::Spectrum,
    /// Wall-clock for the whole job.
    pub elapsed: std::time::Duration,
    /// Tiles executed via PJRT / natively.
    pub pjrt_tiles: usize,
    pub native_tiles: usize,
}

struct JobState {
    spec: Arc<JobSpec>,
    /// Planned symbol→SVD state shared by every tile of this job.
    /// `None` for jobs routed entirely to a PJRT artifact (no native tiles).
    plan: Option<Arc<SpectralPlan>>,
    values: Mutex<Vec<f64>>,
    remaining: AtomicUsize,
    pjrt_tiles: AtomicUsize,
    native_tiles: AtomicUsize,
    started: Instant,
    done_tx: mpsc::Sender<Result<JobResult>>,
    /// Artifact chosen for this job (None = native).
    artifact: Option<ArtifactSpec>,
    /// Pre-converted f32 weights for the PJRT path.
    weights_f32: Vec<f32>,
}

enum Work {
    Tile { state: Arc<JobState>, tile: Tile },
    Shutdown,
}

/// The tile scheduler & worker pool.
pub struct Scheduler {
    work_tx: mpsc::SyncSender<Work>,
    workers: Vec<std::thread::JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    config: SchedulerConfig,
    executor: Option<PjrtExecutor>,
}

impl Scheduler {
    /// Start the pool. If `executor` is `Some`, jobs whose shape matches an
    /// artifact may run on PJRT (per their backend policy).
    pub fn start(config: SchedulerConfig, executor: Option<PjrtExecutor>) -> Self {
        let mut config = config;
        config.workers = resolve_threads(config.workers);
        let (work_tx, work_rx) = mpsc::sync_channel::<Work>(config.queue_depth.max(1) * 4);
        let work_rx = Arc::new(Mutex::new(work_rx));
        let metrics = Arc::new(Metrics::default());
        let mut workers = Vec::with_capacity(config.workers);
        for w in 0..config.workers.max(1) {
            let rx = Arc::clone(&work_rx);
            let metrics = Arc::clone(&metrics);
            let executor = executor.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("lfa-worker-{w}"))
                    .spawn(move || worker_loop(rx, metrics, executor))
                    .expect("spawning worker"),
            );
        }
        Self { work_tx, workers, metrics, config, executor }
    }

    /// Convenience: native-only scheduler (`workers == 0` = auto).
    pub fn native(workers: usize) -> Self {
        Self::start(SchedulerConfig { workers, ..Default::default() }, None)
    }

    /// Submit a job; returns a receiver for its result. Blocks (backpressure)
    /// if the work queue is full. The job's [`SpectralPlan`] is built here,
    /// once — tiles only execute.
    pub fn submit(&self, spec: JobSpec) -> mpsc::Receiver<Result<JobResult>> {
        let (done_tx, done_rx) = mpsc::channel();
        self.metrics.jobs_submitted.fetch_add(1, Ordering::Relaxed);
        let spec = Arc::new(spec);
        let artifact = self.pick_artifact(&spec);
        let tile_rows = match &artifact {
            Some(a) => a.tile_rows,
            None => spec.effective_tile_rows(self.config.workers),
        };
        let tiles: Vec<(usize, usize)> = {
            let mut v = Vec::new();
            let mut lo = 0;
            while lo < spec.n {
                v.push((lo, (lo + tile_rows).min(spec.n)));
                lo += tile_rows;
            }
            v
        };
        let weights_f32 = if artifact.is_some() {
            spec.kernel.data.iter().map(|&v| v as f32).collect()
        } else {
            Vec::new()
        };
        // Jobs with a matching artifact run every tile on PJRT and never
        // touch the native path — skip the planning cost for them.
        let plan = if artifact.is_none() {
            Some(Arc::new(SpectralPlan::new(
                &spec.kernel,
                spec.n,
                spec.m,
                LfaOptions { solver: spec.solver, threads: 1, ..Default::default() },
            )))
        } else {
            None
        };
        let state = Arc::new(JobState {
            spec: Arc::clone(&spec),
            plan,
            values: Mutex::new(vec![0.0; spec.total_values()]),
            remaining: AtomicUsize::new(tiles.len()),
            pjrt_tiles: AtomicUsize::new(0),
            native_tiles: AtomicUsize::new(0),
            started: Instant::now(),
            done_tx,
            artifact,
            weights_f32,
        });
        for (lo, hi) in tiles {
            self.metrics.tiles_dispatched.fetch_add(1, Ordering::Relaxed);
            let tile = Tile { job: Arc::clone(&spec), row_lo: lo, row_hi: hi };
            // SyncSender blocks when full — this is the backpressure point.
            self.work_tx
                .send(Work::Tile { state: Arc::clone(&state), tile })
                .expect("worker pool is gone");
        }
        done_rx
    }

    /// Submit and wait.
    pub fn run(&self, spec: JobSpec) -> Result<JobResult> {
        let rx = self.submit(spec);
        rx.recv().map_err(|_| err!("job dropped without a result"))?
    }

    fn pick_artifact(&self, spec: &JobSpec) -> Option<ArtifactSpec> {
        if self.executor.is_none() || spec.backend == Backend::Native {
            return None;
        }
        let k = &spec.kernel;
        crate::runtime::select(
            &self.config.artifacts,
            spec.n,
            spec.m,
            k.c_out,
            k.c_in,
            k.kh,
            k.kw,
            true,
        )
        .cloned()
        // Explicit PJRT requested but no artifact: the job fails in the
        // worker; keeping submit() infallible.
    }

    /// Graceful shutdown: waits for queued work to finish.
    pub fn shutdown(self) {
        for _ in &self.workers {
            let _ = self.work_tx.send(Work::Shutdown);
        }
        drop(self.work_tx);
        for w in self.workers {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    rx: Arc<Mutex<mpsc::Receiver<Work>>>,
    metrics: Arc<Metrics>,
    executor: Option<PjrtExecutor>,
) {
    loop {
        let work = {
            let guard = rx.lock().expect("queue poisoned");
            guard.recv()
        };
        match work {
            Ok(Work::Tile { state, tile }) => {
                let t0 = Instant::now();
                let outcome = run_tile(&state, &tile, executor.as_ref());
                let used_pjrt = matches!(outcome, Ok(true));
                match outcome {
                    Ok(_) => {
                        metrics.record_tile(tile.num_values(), t0.elapsed(), used_pjrt);
                        if used_pjrt {
                            state.pjrt_tiles.fetch_add(1, Ordering::Relaxed);
                        } else {
                            state.native_tiles.fetch_add(1, Ordering::Relaxed);
                        }
                        if state.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                            finish_job(&state, &metrics);
                        }
                    }
                    Err(e) => {
                        metrics.jobs_failed.fetch_add(1, Ordering::Relaxed);
                        let _ = state.done_tx.send(Err(e));
                    }
                }
            }
            Ok(Work::Shutdown) | Err(_) => return,
        }
    }
}

/// Execute one tile. Returns Ok(true) if it ran via PJRT.
fn run_tile(state: &JobState, tile: &Tile, executor: Option<&PjrtExecutor>) -> Result<bool> {
    let spec = &state.spec;
    let r = spec.rank();
    let (values, used_pjrt): (Vec<f64>, bool) = match (&state.artifact, executor) {
        (Some(art), Some(exec)) => {
            // PJRT path: the artifact computes `art.tile_rows` rows per call.
            let mut vals = Vec::with_capacity(tile.num_values());
            let mut row = tile.row_lo;
            while row < tile.row_hi {
                let reply = exec.run_tile(art, &state.weights_f32, row as i32)?;
                let take = ((tile.row_hi - row).min(art.tile_rows)) * spec.m * r;
                vals.extend(reply.values[..take].iter().map(|&v| v as f64));
                row += art.tile_rows;
            }
            (vals, true)
        }
        _ => {
            if state.artifact.is_none() && spec.backend == Backend::Pjrt {
                return Err(err!(
                    "job {}: PJRT backend requested but no artifact matches \
                     (n={}, c_out={}, c_in={}); run `make artifacts` or use Backend::Auto",
                    spec.id,
                    spec.n,
                    spec.kernel.c_out,
                    spec.kernel.c_in
                ));
            }
            // Native path: execute against the job's shared plan. Workspace
            // checkout reuses the buffers of whichever worker last ran a
            // tile of this job — no per-tile symbol state rebuild.
            let plan = state.plan.as_ref().expect("native jobs always carry a plan");
            let mut vals = vec![0.0f64; tile.num_values()];
            plan.execute_rows_pooled(tile.row_lo, tile.row_hi, &mut vals);
            (vals, false)
        }
    };
    let base = tile.row_lo * spec.m * r;
    let mut buf = state.values.lock().expect("values poisoned");
    buf[base..base + values.len()].copy_from_slice(&values);
    Ok(used_pjrt)
}

fn finish_job(state: &JobState, metrics: &Metrics) {
    let spec = &state.spec;
    let values = std::mem::take(&mut *state.values.lock().expect("values poisoned"));
    let spectrum = lfa::Spectrum {
        n: spec.n,
        m: spec.m,
        c_out: spec.kernel.c_out,
        c_in: spec.kernel.c_in,
        values,
    };
    metrics.jobs_completed.fetch_add(1, Ordering::Relaxed);
    let _ = state.done_tx.send(Ok(JobResult {
        id: spec.id.clone(),
        spectrum,
        elapsed: state.started.elapsed(),
        pjrt_tiles: state.pjrt_tiles.load(Ordering::Relaxed),
        native_tiles: state.native_tiles.load(Ordering::Relaxed),
    }));
}
