//! # conv-svd-lfa
//!
//! Efficient singular value decomposition of convolutional mappings by
//! **Local Fourier Analysis** (LFA) — a reproduction of van Betteray,
//! Rottmann & Kahl (2025) as a three-layer Rust + JAX + Pallas system.
//!
//! A convolution `A : R^{m×n×c_in} → R^{m×n×c_out}` with periodic boundary
//! conditions block-diagonalizes in the Fourier basis: for each frequency
//! `k` the *symbol* `A_k = Σ_y M_y e^{2πi⟨k,y⟩}` is a small `c_out×c_in`
//! complex matrix, and the SVDs of all `n·m` symbols together form the full
//! SVD of `A` in `O(n·m·c³)` — a `log n` factor better than the FFT route
//! (Sedghi et al. 2019) and embarrassingly parallel across frequencies.

pub mod cli;
pub mod numeric;
pub mod linalg;
pub mod fft;
pub mod conv;
pub mod lfa;
pub mod baselines;
pub mod spectral;
pub mod runtime;
pub mod coordinator;
pub mod model;
pub mod report;
pub mod bench_util;
pub mod testing;

pub use numeric::{c64, C64, CMat, Layout, Mat, Pcg64};
