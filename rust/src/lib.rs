//! # conv-svd-lfa
//!
//! Efficient singular value decomposition of convolutional mappings by
//! **Local Fourier Analysis** (LFA) — a reproduction of van Betteray,
//! Rottmann & Kahl (2025) as a three-layer Rust + JAX + Pallas system.
//!
//! A convolution `A : R^{m×n×c_in} → R^{m×n×c_out}` with periodic boundary
//! conditions block-diagonalizes in the Fourier basis: for each frequency
//! `k` the *symbol* `A_k = Σ_y M_y e^{2πi⟨k,y⟩}` is a small `c_out×c_in`
//! complex matrix, and the SVDs of all `n·m` symbols together form the full
//! SVD of `A` in `O(n·m·c³)` — a `log n` factor better than the FFT route
//! (Sedghi et al. 2019) and embarrassingly parallel across frequencies.
//!
//! ## Architecture: three layers around one engine
//!
//! At the center sits [`engine::SpectralPlan`] — the planned,
//! allocation-free execution core. A plan is built once per
//! `(kernel, grid, stride, layout, solver, threads)` and executed many
//! times: it precomputes the twiddle/phase tables, owns pooled per-worker
//! scratch workspaces, and fuses symbol computation with the per-frequency
//! SVD so nothing is allocated per frequency. Executions answer a
//! [`engine::SpectrumRequest`]: the **full** spectrum, or only the **top-k**
//! values per frequency via warm-started Krylov iteration — the partial
//! regime that spectral-norm clipping, Lipschitz certification and
//! low-rank compression actually consume. Because real kernels give
//! `A(−θ) = conj(A(θ))`, every full-grid execution folds the dual grid to
//! a fundamental domain of `θ → −θ` by default ([`lfa::Fold`]) — half the
//! SVDs, the other half mirrored. **Structured convolutions** are
//! first-class: grouped kernels solve block-diagonal symbols (`g`
//! independent blocks per frequency — `g²`× cheaper, depthwise
//! degenerating to scalars), dilation is a phase-table change, and
//! transposed convolutions solve the adjoint symbol (forward singular
//! values bitwise, `U↔V` swapped). See `ARCHITECTURE.md` for the
//! full picture, `docs/PAPER_MAP.md` for the paper→code map (which
//! section, equation, figure and table each module reproduces), and
//! `docs/WORKLOADS.md` for the supported-convolution matrix — which
//! engine path serves each variant × stride × layout × fold × precision
//! × top-k cell, and the accuracy contract it is pinned to.
//!
//! - **L1 — numeric/linalg primitives**: [`numeric`] (complex arithmetic,
//!   layout-aware matrices, deterministic PRNG), [`linalg`] (one-sided
//!   Jacobi SVD with reusable scratch, Hermitian Jacobi eigensolver,
//!   Golub–Reinsch reference SVD, QR, power iteration), [`fft`].
//! - **L2 — LFA core**: [`engine`] (the plan, whole-model
//!   [`engine::ModelPlan`], backends, and the content-addressed
//!   [`engine::SpectralCache`] serving repeat audits as hash lookups),
//!   [`lfa`] (symbols, spectra, strided
//!   crystal-torus machinery — thin wrappers over the engine), [`conv`],
//!   [`baselines`] (FFT/explicit routes sharing the engine's SVD stage),
//!   [`spectral`] (clipping, low-rank compression, pseudo-inverse —
//!   consumers of the planned `FullSvd`).
//! - **L3 — coordinator/service**: [`coordinator`] (frequency-tile
//!   scheduler whose tiles execute against one shared plan per job — and,
//!   for whole models, one shared [`engine::ModelPlan`] per job — with
//!   cache-before-tiling on every native path, metrics,
//!   the [`coordinator::SpectralService`] API), [`runtime`]
//!   (AOT artifact manifest; PJRT execution behind the off-by-default
//!   `pjrt` feature), [`cli`] / [`model`] / [`report`] around them.
//!
//! Thread counts follow one convention everywhere (`lfa`, scheduler, CLI):
//! `0` means auto (`available_parallelism`); see
//! [`engine::resolve_threads`].
//!
//! ## Quick start
//!
//! ```
//! use conv_svd_lfa::conv::ConvKernel;
//! use conv_svd_lfa::engine::SpectralPlan;
//! use conv_svd_lfa::lfa::LfaOptions;
//! use conv_svd_lfa::numeric::Pcg64;
//!
//! let mut rng = Pcg64::seeded(7);
//! let kernel = ConvKernel::random_he(4, 4, 3, 3, &mut rng);
//! // Plan once …
//! let plan = SpectralPlan::new(&kernel, 16, 16, LfaOptions::default());
//! // … execute many times (training-loop clipping, repeated audits).
//! let spectrum = plan.execute();
//! assert_eq!(spectrum.num_values(), 16 * 16 * 4);
//! assert!(spectrum.sigma_max() > 0.0);
//! ```
//!
//! ## Whole-model quick start
//!
//! A whole CNN is one planned object: [`engine::ModelPlan`] plans every
//! conv layer once, batches equal-shape layers into groups sharing one
//! workspace pool, and executes all layers as a single sweep. The same
//! plan then serves audits ([`engine::ModelPlan::execute`]), training-loop
//! clipping (`clip_all`) and compression (`lowrank_all`).
//!
//! ```
//! use conv_svd_lfa::engine::ModelPlan;
//! use conv_svd_lfa::lfa::LfaOptions;
//! use conv_svd_lfa::model::ModelConfig;
//!
//! let model = ModelConfig::parse(
//!     "name = \"tiny\"\nseed = 7\n\
//!      [[layer]]\nname = \"c1\"\nc_in = 3\nc_out = 4\nheight = 8\nwidth = 8\n\
//!      [[layer]]\nname = \"c2\"\nc_in = 3\nc_out = 4\nheight = 8\nwidth = 8\n",
//! )
//! .unwrap();
//! // Plan all layers once; c1 and c2 share one 4x3 workspace group …
//! let plan = ModelPlan::build(&model, LfaOptions::default()).unwrap();
//! assert_eq!(plan.group_count(), 1);
//! // … and execute the whole model as one batched sweep.
//! let spectra = plan.execute();
//! assert_eq!(spectra.num_values(), 2 * 8 * 8 * 3);
//! assert!(spectra.lipschitz_upper_bound() > 0.0);
//! // Only need the extremes? The top-k sweep computes exactly those —
//! // same Lipschitz bound, a fraction of the work.
//! let (bound, iterations) = plan.lipschitz_bound_topk();
//! assert!((bound - spectra.lipschitz_upper_bound()).abs() < 1e-7 * bound);
//! assert!(iterations > 0);
//! ```
//!
//! ## Structured convolutions
//!
//! Grouped, depthwise, dilated and transposed convolutions are built with
//! the [`conv::ConvKernel`] structure builders and run on the same planned
//! engine — `docs/WORKLOADS.md` has the full matrix. A **depthwise audit**:
//! the symbol is block diagonal with one scalar per channel, so each
//! per-frequency "SVD" costs `O(c)` instead of `O(c³)`, and the spectrum
//! (and its cheap top-k extremes) come out exactly as for any dense layer:
//!
//! ```
//! use conv_svd_lfa::conv::ConvKernel;
//! use conv_svd_lfa::engine::SpectralPlan;
//! use conv_svd_lfa::lfa::LfaOptions;
//! use conv_svd_lfa::numeric::Pcg64;
//!
//! let mut rng = Pcg64::seeded(11);
//! // Depthwise = groups == channels; the stored kernel is 4×1×3×3 and
//! // `c_in` names the *per-group* input channels (total = c_in · groups).
//! let depthwise = ConvKernel::random_he(4, 1, 3, 3, &mut rng).with_groups(4);
//! assert_eq!(depthwise.c_in_total(), 4);
//! assert!(!depthwise.is_dense());
//!
//! let plan = SpectralPlan::new(&depthwise, 8, 8, LfaOptions::default());
//! let full = plan.execute();
//! // Grouping never changes the singular-value count per frequency.
//! assert_eq!(full.num_values(), 8 * 8 * 4);
//! // The warm-started top-k sweep reproduces the extreme exactly.
//! let top = plan.execute_topk(1);
//! assert!((full.sigma_max() - top.spectrum.sigma_max()).abs() < 1e-8);
//! ```
//!
//! A **transposed-conv Lipschitz bound**: the transposed operator's symbol
//! is the adjoint `A_k^H`, so its singular values — and therefore the
//! layer's Lipschitz constant `σ_max` — are *bitwise* those of the forward
//! operator; only the factor roles and the reported operator shape swap:
//!
//! ```
//! use conv_svd_lfa::conv::ConvKernel;
//! use conv_svd_lfa::engine::SpectralPlan;
//! use conv_svd_lfa::lfa::LfaOptions;
//! use conv_svd_lfa::numeric::Pcg64;
//!
//! let mut rng = Pcg64::seeded(23);
//! // A decoder-style up-convolution: the adjoint of a 3→6 forward conv.
//! let forward = ConvKernel::random_he(6, 3, 3, 3, &mut rng);
//! let decoder = forward.clone().with_transposed(true);
//!
//! let opts = LfaOptions::default();
//! let fwd = SpectralPlan::new(&forward, 8, 8, opts).execute();
//! let adj = SpectralPlan::new(&decoder, 8, 8, opts).execute();
//! // ‖Aᴴ‖₂ = ‖A‖₂ — the adjoint's Lipschitz bound is the forward one,
//! // down to the last bit (the same forward blocks are solved).
//! assert_eq!(fwd.sigma_max(), adj.sigma_max());
//! assert_eq!(fwd.num_values(), adj.num_values());
//! ```

// The codebase favors explicit index loops that mirror the paper's sums;
// these lints are stylistic there, not defects.
#![allow(clippy::needless_range_loop, clippy::too_many_arguments)]

pub mod cli;
pub mod error;
pub mod numeric;
pub mod linalg;
pub mod fft;
pub mod conv;
pub mod engine;
pub mod lfa;
pub mod baselines;
pub mod spectral;
pub mod runtime;
pub mod coordinator;
pub mod model;
pub mod report;
pub mod bench_util;
pub mod testing;

pub use engine::{ModelPlan, SpectralBackend, SpectralPlan};
pub use error::{Error, ErrorKind, Result};
pub use numeric::{c64, C64, CMat, Layout, Mat, Pcg64};
