//! Built-in model configurations — the synthetic stand-ins for the
//! "PyTorch convolutional weight tensors" of the paper's experiments (the
//! paper uses random tensors too; §IV "3 weight tensors, each with 16 input
//! and output channels").

use super::config::{Init, LayerConfig, ModelConfig};

fn layer(name: &str, c_in: usize, c_out: usize, hw: usize) -> LayerConfig {
    LayerConfig {
        name: name.to_string(),
        c_in,
        c_out,
        kh: 3,
        kw: 3,
        height: hw,
        width: hw,
        stride: 1,
        init: Init::He,
    }
}

/// The paper's benchmark shape: `c = 16` channels at a given resolution.
pub fn paper_layer(n: usize) -> ModelConfig {
    ModelConfig {
        name: format!("paper-c16-n{n}"),
        seed: 2025,
        layers: vec![layer("conv", 16, 16, n)],
    }
}

/// LeNet-style stack (tiny; explicit baseline still feasible).
pub fn lenet() -> ModelConfig {
    ModelConfig {
        name: "lenet".into(),
        seed: 1,
        layers: vec![layer("conv1", 1, 6, 28), layer("conv2", 6, 16, 14)],
    }
}

/// VGG-style stack on 32×32 inputs.
pub fn vgg_small() -> ModelConfig {
    ModelConfig {
        name: "vgg-small".into(),
        seed: 2,
        layers: vec![
            layer("conv1_1", 3, 16, 32),
            layer("conv1_2", 16, 16, 32),
            layer("conv2_1", 16, 32, 16),
            layer("conv2_2", 32, 32, 16),
            layer("conv3_1", 32, 64, 8),
            layer("conv3_2", 64, 64, 8),
        ],
    }
}

/// ResNet-ish stack on 32×32 (CIFAR-style stem + 3 stages).
pub fn resnet20ish() -> ModelConfig {
    let mut layers = vec![layer("stem", 3, 16, 32)];
    for b in 0..3 {
        layers.push(layer(&format!("stage1.b{b}.conv1"), 16, 16, 32));
        layers.push(layer(&format!("stage1.b{b}.conv2"), 16, 16, 32));
    }
    for b in 0..3 {
        let c_in = if b == 0 { 16 } else { 32 };
        layers.push(layer(&format!("stage2.b{b}.conv1"), c_in, 32, 16));
        layers.push(layer(&format!("stage2.b{b}.conv2"), 32, 32, 16));
    }
    for b in 0..3 {
        let c_in = if b == 0 { 32 } else { 64 };
        layers.push(layer(&format!("stage3.b{b}.conv1"), c_in, 64, 8));
        layers.push(layer(&format!("stage3.b{b}.conv2"), 64, 64, 8));
    }
    ModelConfig { name: "resnet20ish".into(), seed: 3, layers }
}

/// Look up a builtin by name.
pub fn builtin(name: &str) -> Option<ModelConfig> {
    match name {
        "lenet" => Some(lenet()),
        "vgg-small" => Some(vgg_small()),
        "resnet20ish" => Some(resnet20ish()),
        _ => name
            .strip_prefix("paper-c16-n")
            .and_then(|n| n.parse().ok())
            .map(paper_layer),
    }
}

/// Names of all builtins (for `--help`).
pub fn builtin_names() -> &'static [&'static str] {
    &["lenet", "vgg-small", "resnet20ish", "paper-c16-n<N>"]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_resolve() {
        assert_eq!(builtin("lenet").unwrap().layers.len(), 2);
        assert_eq!(builtin("resnet20ish").unwrap().layers.len(), 19);
        assert_eq!(builtin("paper-c16-n64").unwrap().layers[0].height, 64);
        assert!(builtin("nope").is_none());
    }

    #[test]
    fn channel_chain_is_consistent() {
        for model in [lenet(), vgg_small(), resnet20ish()] {
            // c_in of each non-stem layer equals some previous layer's c_out
            // (weak sanity: just check monotonic plausibility and nonzero).
            for l in &model.layers {
                assert!(l.c_in > 0 && l.c_out > 0);
            }
        }
    }

    #[test]
    fn vgg_total_values() {
        let m = vgg_small();
        let want: usize = m.layers.iter().map(|l| l.num_values()).sum();
        assert_eq!(m.total_values(), want);
        assert_eq!(want, 37_888, "3072+16384+4096+8192+2048+4096");
    }
}
