//! Built-in model configurations — the synthetic stand-ins for the
//! "PyTorch convolutional weight tensors" of the paper's experiments (the
//! paper uses random tensors too; §IV "3 weight tensors, each with 16 input
//! and output channels").

use super::config::{Init, LayerConfig, ModelConfig};

fn layer(name: &str, c_in: usize, c_out: usize, hw: usize) -> LayerConfig {
    LayerConfig {
        name: name.to_string(),
        c_in,
        c_out,
        kh: 3,
        kw: 3,
        height: hw,
        width: hw,
        stride: 1,
        groups: 1,
        dilation: 1,
        transposed: false,
        init: Init::He,
    }
}

/// Depthwise 3×3 layer (`groups = c`), the MobileNet building block.
fn dw_layer(name: &str, c: usize, hw: usize) -> LayerConfig {
    LayerConfig { groups: c, ..layer(name, c, c, hw) }
}

/// Pointwise 1×1 layer — the channel-mixing half of a separable block.
fn pw_layer(name: &str, c_in: usize, c_out: usize, hw: usize) -> LayerConfig {
    LayerConfig { kh: 1, kw: 1, ..layer(name, c_in, c_out, hw) }
}

/// The paper's benchmark shape: `c = 16` channels at a given resolution.
pub fn paper_layer(n: usize) -> ModelConfig {
    ModelConfig {
        name: format!("paper-c16-n{n}"),
        seed: 2025,
        layers: vec![layer("conv", 16, 16, n)],
    }
}

/// LeNet-style stack (tiny; explicit baseline still feasible).
pub fn lenet() -> ModelConfig {
    ModelConfig {
        name: "lenet".into(),
        seed: 1,
        layers: vec![layer("conv1", 1, 6, 28), layer("conv2", 6, 16, 14)],
    }
}

/// VGG-style stack on 32×32 inputs.
pub fn vgg_small() -> ModelConfig {
    ModelConfig {
        name: "vgg-small".into(),
        seed: 2,
        layers: vec![
            layer("conv1_1", 3, 16, 32),
            layer("conv1_2", 16, 16, 32),
            layer("conv2_1", 16, 32, 16),
            layer("conv2_2", 32, 32, 16),
            layer("conv3_1", 32, 64, 8),
            layer("conv3_2", 64, 64, 8),
        ],
    }
}

/// ResNet-ish stack on 32×32 (CIFAR-style stem + 3 stages).
pub fn resnet20ish() -> ModelConfig {
    let mut layers = vec![layer("stem", 3, 16, 32)];
    for b in 0..3 {
        layers.push(layer(&format!("stage1.b{b}.conv1"), 16, 16, 32));
        layers.push(layer(&format!("stage1.b{b}.conv2"), 16, 16, 32));
    }
    for b in 0..3 {
        let c_in = if b == 0 { 16 } else { 32 };
        layers.push(layer(&format!("stage2.b{b}.conv1"), c_in, 32, 16));
        layers.push(layer(&format!("stage2.b{b}.conv2"), 32, 32, 16));
    }
    for b in 0..3 {
        let c_in = if b == 0 { 32 } else { 64 };
        layers.push(layer(&format!("stage3.b{b}.conv1"), c_in, 64, 8));
        layers.push(layer(&format!("stage3.b{b}.conv2"), 64, 64, 8));
    }
    ModelConfig { name: "resnet20ish".into(), seed: 3, layers }
}

/// MobileNet-style stack on 32×32 inputs exercising every structured
/// convolution the engine audits: depthwise-separable blocks (depthwise
/// 3×3 + pointwise 1×1), a dilated context layer, and a transposed
/// decoder layer.
pub fn mobile_ish() -> ModelConfig {
    ModelConfig {
        name: "mobile-ish".into(),
        seed: 4,
        layers: vec![
            layer("stem", 3, 8, 32),
            dw_layer("block1.dw", 8, 32),
            pw_layer("block1.pw", 8, 16, 32),
            dw_layer("block2.dw", 16, 16),
            pw_layer("block2.pw", 16, 32, 16),
            LayerConfig { dilation: 2, ..layer("context.dilated", 32, 32, 16) },
            LayerConfig { transposed: true, ..layer("decoder.up", 32, 16, 16) },
        ],
    }
}

/// Look up a builtin by name.
pub fn builtin(name: &str) -> Option<ModelConfig> {
    match name {
        "lenet" => Some(lenet()),
        "vgg-small" => Some(vgg_small()),
        "resnet20ish" => Some(resnet20ish()),
        "mobile-ish" => Some(mobile_ish()),
        _ => name
            .strip_prefix("paper-c16-n")
            .and_then(|n| n.parse().ok())
            .map(paper_layer),
    }
}

/// Names of all builtins (for `--help`).
pub fn builtin_names() -> &'static [&'static str] {
    &["lenet", "vgg-small", "resnet20ish", "mobile-ish", "paper-c16-n<N>"]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_resolve() {
        assert_eq!(builtin("lenet").unwrap().layers.len(), 2);
        assert_eq!(builtin("resnet20ish").unwrap().layers.len(), 19);
        assert_eq!(builtin("paper-c16-n64").unwrap().layers[0].height, 64);
        assert_eq!(builtin("mobile-ish").unwrap().layers.len(), 7);
        assert!(builtin("nope").is_none());
    }

    #[test]
    fn mobile_ish_is_structured_and_materializes() {
        let m = mobile_ish();
        assert!(m.layers.iter().any(|l| l.groups > 1), "has a depthwise layer");
        assert!(m.layers.iter().any(|l| l.dilation > 1), "has a dilated layer");
        assert!(m.layers.iter().any(|l| l.transposed), "has a transposed layer");
        for l in &m.layers {
            assert_eq!(l.c_in % l.groups, 0);
            assert_eq!(l.c_out % l.groups, 0);
            let k = l.materialize(m.seed);
            assert_eq!(k.c_in_total(), l.c_in);
            assert_eq!(k.c_out, l.c_out);
        }
        // Depthwise block: per-group width 1 ⇒ scalar per-group symbols.
        let dw = m.layers.iter().find(|l| l.groups > 1).unwrap();
        assert_eq!(dw.materialize(m.seed).c_in, 1);
    }

    #[test]
    fn channel_chain_is_consistent() {
        for model in [lenet(), vgg_small(), resnet20ish()] {
            // c_in of each non-stem layer equals some previous layer's c_out
            // (weak sanity: just check monotonic plausibility and nonzero).
            for l in &model.layers {
                assert!(l.c_in > 0 && l.c_out > 0);
            }
        }
    }

    #[test]
    fn vgg_total_values() {
        let m = vgg_small();
        let want: usize = m.layers.iter().map(|l| l.num_values()).sum();
        assert_eq!(m.total_values(), want);
        assert_eq!(want, 37_888, "3072+16384+4096+8192+2048+4096");
    }
}
