//! Model configuration: a TOML-subset parser (no serde/toml in the offline
//! crate set) describing the conv layers of a CNN to audit.
//!
//! Format:
//!
//! ```toml
//! name = "resnet-ish"
//! seed = 42
//!
//! [[layer]]
//! name   = "conv1"
//! c_in   = 3
//! c_out  = 16
//! kernel = 3        # kh = kw
//! height = 32
//! width  = 32
//! stride = 1        # must divide height and width
//! init   = "he"     # he | glorot | const:<value> (const:nan = divergence drill)
//!
//! # Structured convolutions (all optional — defaults are dense):
//! groups     = 1        # channel groups; must divide c_in and c_out
//! dilation   = 1        # tap spacing (à-trous)
//! transposed = false    # audit the adjoint operator (true | false | 1 | 0)
//! ```
//!
//! `c_in` is always the **total** input channel count — the shape an
//! activation tensor actually has. Grouped layers divide it internally
//! (`groups = c_in` with per-group width 1 is depthwise).

use crate::bail;
use crate::conv::ConvKernel;
use crate::error::{Context, Result};
use crate::numeric::Pcg64;

/// Weight initialization scheme. `Const` fills every tap with one value —
/// mainly a test/diagnostic hook: `init = "const:nan"` is how the
/// numerical-health suite drives non-finite weights through the model and
/// daemon submit paths (a diverged training loop in one line of TOML).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Init {
    He,
    Glorot,
    Const(f64),
}

/// One conv layer to analyze.
#[derive(Clone, Debug)]
pub struct LayerConfig {
    pub name: String,
    /// **Total** input channels (the activation tensor's width). Grouped
    /// layers store `c_in / groups` per-group channels in the kernel.
    pub c_in: usize,
    pub c_out: usize,
    pub kh: usize,
    pub kw: usize,
    pub height: usize,
    pub width: usize,
    /// Output subsampling stride (`C = D_s ∘ A`); 1 = dense.
    pub stride: usize,
    /// Channel groups (1 = dense, `c_in` with `c_out = c_in` = depthwise).
    pub groups: usize,
    /// Tap spacing (1 = ordinary convolution).
    pub dilation: usize,
    /// Audit the adjoint operator (transposed / "deconvolution") instead
    /// of the forward mapping. Singular values are identical; the factors
    /// and the operator shape swap.
    pub transposed: bool,
    pub init: Init,
}

impl LayerConfig {
    /// Create the weight tensor for this layer. The stream id is derived
    /// from the layer name so layers are independent but reproducible.
    pub fn materialize(&self, seed: u64) -> ConvKernel {
        let stream = self.name.bytes().fold(0xcbf29ce484222325u64, |h, b| {
            (h ^ b as u64).wrapping_mul(0x100000001b3)
        });
        let mut rng = Pcg64::new(seed, stream);
        // The kernel stores per-group input width (PyTorch OIHW grouped
        // convention); He/Glorot fan-in is the per-group fan-in, which is
        // what a grouped layer's forward pass actually sums over.
        let cg = self.c_in / self.groups;
        let k = match self.init {
            Init::He => ConvKernel::random_he(self.c_out, cg, self.kh, self.kw, &mut rng),
            Init::Glorot => ConvKernel::random_glorot(self.c_out, cg, self.kh, self.kw, &mut rng),
            Init::Const(c) => {
                let mut k = ConvKernel::zeros(self.c_out, cg, self.kh, self.kw);
                k.data.fill(c);
                k
            }
        };
        k.with_groups(self.groups).with_dilation(self.dilation).with_transposed(self.transposed)
    }

    /// Number of singular values this layer's mapping has. For stride `s`
    /// the dual grid is the coarse `(h/s)×(w/s)` torus and each frequency's
    /// block is `c_out × s²·c_in`. Grouping does not change the count —
    /// `groups` blocks of `min(c_out/g, s²·c_in/g)` values sum to
    /// `min(c_out, s²·c_in)` — and transposition is rank-preserving.
    pub fn num_values(&self) -> usize {
        let s = self.stride;
        (self.height / s) * (self.width / s) * self.c_out.min(s * s * self.c_in)
    }
}

/// A model: an ordered list of conv layers.
#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub name: String,
    pub seed: u64,
    pub layers: Vec<LayerConfig>,
}

impl ModelConfig {
    /// Parse the TOML-subset format above.
    pub fn parse(text: &str) -> Result<ModelConfig> {
        let mut name = "model".to_string();
        let mut seed = 0u64;
        let mut layers: Vec<LayerConfig> = Vec::new();
        let mut in_layer = false;

        // Current layer fields.
        let mut cur: Option<PartialLayer> = None;

        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if line == "[[layer]]" {
                if let Some(p) = cur.take() {
                    layers.push(p.build(lineno)?);
                }
                cur = Some(PartialLayer::default());
                in_layer = true;
                continue;
            }
            if line.starts_with('[') {
                bail!("line {}: unknown section {line}", lineno + 1);
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            let k = k.trim();
            let v = v.trim().trim_matches('"');
            if !in_layer {
                match k {
                    "name" => name = v.to_string(),
                    "seed" => seed = v.parse().with_context(|| format!("line {}: bad seed", lineno + 1))?,
                    _ => bail!("line {}: unknown top-level key {k}", lineno + 1),
                }
            } else {
                let p = cur.as_mut().expect("in_layer implies cur");
                match k {
                    "name" => p.name = Some(v.to_string()),
                    "c_in" => p.c_in = Some(parse_usize(v, lineno)?),
                    "c_out" => p.c_out = Some(parse_usize(v, lineno)?),
                    "kernel" => {
                        let kk = parse_usize(v, lineno)?;
                        p.kh = Some(kk);
                        p.kw = Some(kk);
                    }
                    "kh" => p.kh = Some(parse_usize(v, lineno)?),
                    "kw" => p.kw = Some(parse_usize(v, lineno)?),
                    "height" => p.height = Some(parse_usize(v, lineno)?),
                    "width" => p.width = Some(parse_usize(v, lineno)?),
                    "stride" => p.stride = Some(parse_usize(v, lineno)?),
                    "groups" => p.groups = Some(parse_usize(v, lineno)?),
                    "dilation" => p.dilation = Some(parse_usize(v, lineno)?),
                    "transposed" => p.transposed = Some(parse_bool(v, lineno)?),
                    "init" => {
                        p.init = Some(match v {
                            "he" => Init::He,
                            "glorot" => Init::Glorot,
                            _ => match v.strip_prefix("const:") {
                                Some(c) => Init::Const(c.parse::<f64>().with_context(|| {
                                    format!("line {}: bad const init value {c}", lineno + 1)
                                })?),
                                None => bail!("line {}: unknown init {v}", lineno + 1),
                            },
                        })
                    }
                    _ => bail!("line {}: unknown layer key {k}", lineno + 1),
                }
            }
        }
        if let Some(p) = cur.take() {
            layers.push(p.build(text.lines().count())?);
        }
        if layers.is_empty() {
            bail!("model config has no [[layer]] sections");
        }
        Ok(ModelConfig { name, seed, layers })
    }

    /// Load from a file path.
    pub fn load(path: &std::path::Path) -> Result<ModelConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading model config {}", path.display()))?;
        Self::parse(&text)
    }

    /// Total singular values across all layers.
    pub fn total_values(&self) -> usize {
        self.layers.iter().map(|l| l.num_values()).sum()
    }
}

fn parse_usize(v: &str, lineno: usize) -> Result<usize> {
    v.parse::<usize>().with_context(|| format!("line {}: bad integer {v}", lineno + 1))
}

fn parse_bool(v: &str, lineno: usize) -> Result<bool> {
    match v {
        "true" | "1" => Ok(true),
        "false" | "0" => Ok(false),
        _ => bail!("line {}: bad boolean {v} (expected true/false/1/0)", lineno + 1),
    }
}

#[derive(Default)]
struct PartialLayer {
    name: Option<String>,
    c_in: Option<usize>,
    c_out: Option<usize>,
    kh: Option<usize>,
    kw: Option<usize>,
    height: Option<usize>,
    width: Option<usize>,
    stride: Option<usize>,
    groups: Option<usize>,
    dilation: Option<usize>,
    transposed: Option<bool>,
    init: Option<Init>,
}

impl PartialLayer {
    fn build(self, lineno: usize) -> Result<LayerConfig> {
        let get = |o: Option<usize>, what: &str| {
            o.with_context(|| format!("layer before line {}: missing {what}", lineno + 1))
        };
        let c_in = get(self.c_in, "c_in")?;
        let c_out = get(self.c_out, "c_out")?;
        let height = get(self.height, "height")?;
        let width = get(self.width, "width")?;
        let kh = self.kh.unwrap_or(3);
        let kw = self.kw.unwrap_or(3);
        if c_in == 0 || c_out == 0 || height == 0 || width == 0 || kh == 0 || kw == 0 {
            bail!("layer before line {}: zero-sized dimension", lineno + 1);
        }
        let stride = self.stride.unwrap_or(1);
        if stride == 0 || height % stride != 0 || width % stride != 0 {
            bail!(
                "layer before line {}: stride {stride} must be nonzero and divide \
                 height {height} and width {width}",
                lineno + 1
            );
        }
        let groups = self.groups.unwrap_or(1);
        if groups == 0 || c_in % groups != 0 || c_out % groups != 0 {
            bail!(
                "layer before line {}: groups {groups} must be nonzero and divide \
                 both c_in {c_in} and c_out {c_out}",
                lineno + 1
            );
        }
        let dilation = self.dilation.unwrap_or(1);
        if dilation == 0 {
            bail!("layer before line {}: dilation must be >= 1", lineno + 1);
        }
        Ok(LayerConfig {
            name: self.name.unwrap_or_else(|| format!("layer{}", lineno)),
            c_in,
            c_out,
            kh,
            kw,
            height,
            width,
            stride,
            groups,
            dilation,
            transposed: self.transposed.unwrap_or(false),
            init: self.init.unwrap_or(Init::He),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
name = "tiny"
seed = 7

[[layer]]
name   = "conv1"
c_in   = 3
c_out  = 8
kernel = 3
height = 16
width  = 16

[[layer]]
name   = "conv2"
c_in   = 8
c_out  = 8
kernel = 3
height = 8
width  = 8
init   = "glorot"
"#;

    #[test]
    fn parses_sample() {
        let m = ModelConfig::parse(SAMPLE).unwrap();
        assert_eq!(m.name, "tiny");
        assert_eq!(m.seed, 7);
        assert_eq!(m.layers.len(), 2);
        assert_eq!(m.layers[0].c_out, 8);
        assert_eq!(m.layers[1].init, Init::Glorot);
        assert_eq!(m.total_values(), 16 * 16 * 3 + 8 * 8 * 8);
    }

    #[test]
    fn materialize_is_deterministic_and_layer_distinct() {
        let m = ModelConfig::parse(SAMPLE).unwrap();
        let k1 = m.layers[0].materialize(m.seed);
        let k2 = m.layers[0].materialize(m.seed);
        assert_eq!(k1.data, k2.data);
        let mut cfg2 = m.layers[0].clone();
        cfg2.name = "other".to_string();
        let k3 = cfg2.materialize(m.seed);
        assert_ne!(k1.data, k3.data);
    }

    #[test]
    fn const_init_parses_and_materializes() {
        let m = ModelConfig::parse(
            "[[layer]]\nc_in = 2\nc_out = 2\nheight = 4\nwidth = 4\ninit = \"const:0.5\"\n",
        )
        .unwrap();
        assert_eq!(m.layers[0].init, Init::Const(0.5));
        let k = m.layers[0].materialize(0);
        assert!(k.data.iter().all(|&w| w == 0.5));
        assert_eq!(k.non_finite_count(), 0);
        // NaN/Inf spellings go through f64::from_str — the health suite's
        // divergence hook.
        let bad = ModelConfig::parse(
            "[[layer]]\nc_in = 2\nc_out = 2\nheight = 4\nwidth = 4\ninit = \"const:nan\"\n",
        )
        .unwrap();
        let k = bad.layers[0].materialize(0);
        assert_eq!(k.non_finite_count(), k.data.len());
        assert!(ModelConfig::parse(
            "[[layer]]\nc_in = 2\nc_out = 2\nheight = 4\nwidth = 4\ninit = \"const:x\"\n"
        )
        .is_err());
    }

    #[test]
    fn rejects_missing_fields() {
        assert!(ModelConfig::parse("[[layer]]\nname = \"x\"\n").is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(ModelConfig::parse("nonsense without equals\n[[layer]]").is_err());
        assert!(ModelConfig::parse("").is_err());
    }

    #[test]
    fn comments_and_defaults() {
        let m = ModelConfig::parse(
            "# top\n[[layer]]\nc_in = 1 # inline\nc_out = 2\nheight = 4\nwidth = 4\n",
        )
        .unwrap();
        assert_eq!(m.layers[0].kh, 3, "kernel defaults to 3");
        assert_eq!(m.layers[0].stride, 1, "stride defaults to 1");
        assert_eq!(m.layers[0].init, Init::He);
    }

    #[test]
    fn structured_layer_parses_and_materializes() {
        let m = ModelConfig::parse(
            "[[layer]]\nname = \"dw\"\nc_in = 8\nc_out = 8\nheight = 8\nwidth = 8\n\
             groups = 8\ndilation = 2\ntransposed = true\n",
        )
        .unwrap();
        let l = &m.layers[0];
        assert_eq!((l.groups, l.dilation, l.transposed), (8, 2, true));
        let k = l.materialize(0);
        // Kernel stores per-group width: depthwise c_in/groups = 1.
        assert_eq!((k.c_out, k.c_in, k.groups), (8, 1, 8));
        assert_eq!(k.c_in_total(), 8);
        assert_eq!((k.dilation, k.transposed), (2, true));
        // Grouping does not change the value count: 8·8·min(8, 8) values.
        assert_eq!(l.num_values(), 8 * 8 * 8);
        // Defaults stay dense.
        let d = ModelConfig::parse("[[layer]]\nc_in = 2\nc_out = 2\nheight = 4\nwidth = 4\n")
            .unwrap();
        let l = &d.layers[0];
        assert_eq!((l.groups, l.dilation, l.transposed), (1, 1, false));
        assert!(l.materialize(0).is_dense());
        // groups must divide both channel counts; dilation must be >= 1.
        assert!(ModelConfig::parse(
            "[[layer]]\nc_in = 3\nc_out = 4\nheight = 4\nwidth = 4\ngroups = 2\n"
        )
        .is_err());
        assert!(ModelConfig::parse(
            "[[layer]]\nc_in = 4\nc_out = 3\nheight = 4\nwidth = 4\ngroups = 2\n"
        )
        .is_err());
        assert!(ModelConfig::parse(
            "[[layer]]\nc_in = 2\nc_out = 2\nheight = 4\nwidth = 4\ndilation = 0\n"
        )
        .is_err());
        assert!(ModelConfig::parse(
            "[[layer]]\nc_in = 2\nc_out = 2\nheight = 4\nwidth = 4\ntransposed = maybe\n"
        )
        .is_err());
    }

    #[test]
    fn strided_layer_counts_and_validation() {
        let m = ModelConfig::parse(
            "[[layer]]\nc_in = 2\nc_out = 16\nheight = 8\nwidth = 8\nstride = 2\n",
        )
        .unwrap();
        assert_eq!(m.layers[0].stride, 2);
        // 4×4 coarse grid, min(16, 4·2) = 8 values per frequency.
        assert_eq!(m.layers[0].num_values(), 4 * 4 * 8);
        // Stride must divide the grid, and must be nonzero.
        assert!(ModelConfig::parse(
            "[[layer]]\nc_in = 1\nc_out = 1\nheight = 8\nwidth = 9\nstride = 2\n"
        )
        .is_err());
        assert!(ModelConfig::parse(
            "[[layer]]\nc_in = 1\nc_out = 1\nheight = 8\nwidth = 8\nstride = 0\n"
        )
        .is_err());
    }
}
