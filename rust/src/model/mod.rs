//! Model configs (TOML-lite) and the builtin zoo used by the audit
//! example, the CLI and the benches.

pub mod config;
pub mod zoo;

pub use config::{Init, LayerConfig, ModelConfig};
