"""AOT lowering: JAX/Pallas pipeline -> HLO *text* artifacts for the rust
PJRT runtime.

HLO text (NOT ``lowered.compile()`` / ``.serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser on
the rust side reassigns ids and round-trips cleanly.  Same recipe as
/opt/xla-example/gen_hlo.py.

Usage:  cd python && python -m compile.aot --out ../artifacts
Emits one ``<name>.hlo.txt`` per config plus ``manifest.txt`` with the
static shapes the rust runtime needs.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import SpectrumConfig, spectrum_fn

# Artifact configs. tile_rows > 0 makes the artifact cover one frequency-row
# tile per execution (the coordinator fans these out across workers);
# tile_rows == 0 bakes the whole grid into a single call.
CONFIGS = [
    SpectrumConfig(n=8, m=8, c_out=4, c_in=4),
    SpectrumConfig(n=16, m=16, c_out=8, c_in=8),
    SpectrumConfig(n=16, m=16, c_out=16, c_in=16),
    SpectrumConfig(n=32, m=32, c_out=16, c_in=16),
    # Tiled variant: 4 frequency rows per execution, shardable across workers.
    SpectrumConfig(n=32, m=32, c_out=16, c_in=16, tile_rows=4),
    SpectrumConfig(n=64, m=64, c_out=16, c_in=16, tile_rows=8),
    # Non-square channel counts exercise the Gram-side swap.
    SpectrumConfig(n=16, m=16, c_out=8, c_in=16),
    SpectrumConfig(n=16, m=16, c_out=16, c_in=8),
]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # CRITICAL: the default HLO printer elides constants with >= 16 elements
    # as "{...}", and xla_extension 0.5.1's text parser silently reads those
    # as ZEROS (no error!). Any traced constant table -- e.g. the Jacobi
    # pair schedule -- would be corrupted. print_metadata must be off too:
    # the new printer emits source_end_line attributes the old parser
    # rejects.
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    opts.print_metadata = False
    return comp.as_hlo_module().to_string(opts)


def lower_config(cfg: SpectrumConfig) -> str:
    w_spec = jax.ShapeDtypeStruct((cfg.c_out, cfg.c_in, cfg.kh, cfg.kw), jnp.float32)
    off_spec = jax.ShapeDtypeStruct((), jnp.int32)
    lowered = jax.jit(spectrum_fn(cfg, interpret=True)).lower(w_spec, off_spec)
    return to_hlo_text(lowered)


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="output directory")
    args = parser.parse_args()
    os.makedirs(args.out, exist_ok=True)
    manifest_lines = []
    for cfg in CONFIGS:
        fname = cfg.name + ".hlo.txt"
        path = os.path.join(args.out, fname)
        text = lower_config(cfg)
        with open(path, "w") as f:
            f.write(text)
        manifest_lines.append(
            f"{cfg.name} n={cfg.n} m={cfg.m} c_out={cfg.c_out} c_in={cfg.c_in} "
            f"kh={cfg.kh} kw={cfg.kw} tile_rows={cfg.rows} rank={cfg.rank} "
            f"sweeps={cfg.sweeps} file={fname}"
        )
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote manifest with {len(manifest_lines)} artifacts")


if __name__ == "__main__":
    main()
