"""Layer-2 JAX model: the LFA spectrum pipeline that gets AOT-lowered.

Pipeline (all shapes static, chosen at lowering time):

    weights [c_out, c_in, kh, kw] f32, row_offset i32
      -> traced phase tables for the frequency-row tile
      -> Pallas symbol kernel      (kernels.lfa_symbol)
      -> Pallas Gram kernel        (kernels.gram)
      -> pure-HLO batched Hermitian Jacobi eigensolver (below)
      -> singular values [tile_rows*m, r] f32, descending per frequency

Constraints honoured here (see DESIGN.md):
  * NO ``jnp.linalg.*`` / ``jnp.fft`` — those lower to jaxlib FFI custom
    calls that xla_extension 0.5.1 (the rust runtime) cannot execute. The
    eigensolver is hand-written from rotations, so the artifact is plain HLO.
  * Complex numbers are carried as (re, im) f32 pairs end-to-end.
  * ``row_offset`` makes the artifact *tileable*: the rust coordinator runs
    the same executable for each frequency-row tile of the grid
    ("embarrassingly parallel", paper section V).
"""

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.gram import gram
from .kernels.lfa_symbol import lfa_symbol


class SpectrumConfig(NamedTuple):
    """Static configuration of one AOT artifact."""

    n: int
    m: int
    c_out: int
    c_in: int
    kh: int = 3
    kw: int = 3
    tile_rows: int = 0  # 0 = whole grid in one call
    sweeps: int = 12

    @property
    def rows(self):
        return self.tile_rows if self.tile_rows else self.n

    @property
    def freqs(self):
        return self.rows * self.m

    @property
    def rank(self):
        return min(self.c_out, self.c_in)

    @property
    def name(self):
        return (
            f"lfa_spectrum_n{self.n}x{self.m}_c{self.c_out}x{self.c_in}"
            f"_k{self.kh}x{self.kw}_t{self.rows}"
        )


def _cmul(ar, ai, br, bi):
    """Complex multiply on (re, im) pairs."""
    return ar * br - ai * bi, ar * bi + ai * br


def traced_phases(cfg: SpectrumConfig, row_offset):
    """Phase tables ``[F, T]`` (re, im) for frequency rows
    ``[row_offset, row_offset + rows)`` — built from iota so the artifact can
    be re-targeted at any tile at runtime."""
    ar, ac = cfg.kh // 2, cfg.kw // 2
    ii = row_offset.astype(jnp.float32) + jnp.arange(cfg.rows, dtype=jnp.float32)
    jj = jnp.arange(cfg.m, dtype=jnp.float32)
    dy = jnp.arange(cfg.kh, dtype=jnp.float32) - ar
    dx = jnp.arange(cfg.kw, dtype=jnp.float32) - ac
    ay = 2.0 * jnp.pi * jnp.outer(ii, dy) / cfg.n  # [rows, kh]
    axx = 2.0 * jnp.pi * jnp.outer(jj, dx) / cfg.m  # [m, kw]
    py_re, py_im = jnp.cos(ay), jnp.sin(ay)
    px_re, px_im = jnp.cos(axx), jnp.sin(axx)
    # outer complex product -> [rows, m, kh, kw]
    pre = (
        py_re[:, None, :, None] * px_re[None, :, None, :]
        - py_im[:, None, :, None] * px_im[None, :, None, :]
    )
    pim = (
        py_re[:, None, :, None] * px_im[None, :, None, :]
        + py_im[:, None, :, None] * px_re[None, :, None, :]
    )
    t = cfg.kh * cfg.kw
    return pre.reshape(cfg.freqs, t), pim.reshape(cfg.freqs, t)


def _pair_schedule(r):
    """Static cyclic pair schedule [(p, q) ...] for r x r Jacobi."""
    return np.array([(p, q) for p in range(r - 1) for q in range(p + 1, r)], dtype=np.int32)


def jacobi_eigvals(g_re, g_im, sweeps):
    """Batched Hermitian Jacobi eigenvalues in pure HLO.

    Compact rotation loop (`lax.fori_loop` over sweeps x pairs) with
    dynamic-index row/column updates. Two artifact-portability constraints
    (discovered by stage-isolated debugging against xla_extension 0.5.1):

    * no ``jnp.linalg`` (lowers to lapack FFI custom calls), and
    * the AOT path must print HLO text with ``print_large_constants=True``
      -- the default printer elides >=16-element constants as ``{...}``,
      which the old HLO text parser silently reads as zeros (the pair
      tables below are exactly such constants). See ``aot.to_hlo_text``.

    Args:
      g_re, g_im: ``[F, r, r]`` Hermitian matrices (im antisymmetric).
      sweeps: fixed number of cyclic sweeps (static; 8-12 suffices for
        r <= 32 in f32).

    Returns:
      ``[F, r]`` eigenvalues, descending.
    """
    f, r, _ = g_re.shape
    if r == 1:
        return g_re[:, :, 0]
    schedule = _pair_schedule(r)
    pairs = jnp.asarray(schedule)
    npairs = schedule.shape[0]
    tiny = jnp.float32(1e-30)

    def rotate(t, carry):
        g_re, g_im = carry
        idx = t % npairs
        p = pairs[idx, 0]
        q = pairs[idx, 1]
        app = g_re[:, p, p]
        aqq = g_re[:, q, q]
        apq_re = g_re[:, p, q]
        apq_im = g_im[:, p, q]
        mag = jnp.sqrt(apq_re * apq_re + apq_im * apq_im)
        safe = mag > (jnp.abs(app) + jnp.abs(aqq)) * jnp.float32(1e-9) + tiny
        inv_mag = jnp.where(safe, 1.0 / jnp.maximum(mag, tiny), 0.0)
        ph_re = jnp.where(safe, apq_re * inv_mag, 1.0)  # e^{i phi}
        ph_im = jnp.where(safe, apq_im * inv_mag, 0.0)
        tau = (aqq - app) * 0.5 * inv_mag
        tt = jnp.sign(tau) / (jnp.abs(tau) + jnp.sqrt(1.0 + tau * tau))
        tt = jnp.where(safe, tt, 0.0)
        c = 1.0 / jnp.sqrt(1.0 + tt * tt)
        s = c * tt
        cb = c[:, None]
        sb = s[:, None]
        php = (ph_re[:, None], ph_im[:, None])  # e^{+i phi}
        phm = (ph_re[:, None], -ph_im[:, None])  # e^{-i phi}

        # Right-multiply by R2 = [[c, s e^{i phi}], [-s e^{-i phi}, c]]:
        #   col_p' = c col_p - s e^{-i phi} col_q
        #   col_q' = s e^{+i phi} col_p + c col_q
        colp_re, colp_im = g_re[:, :, p], g_im[:, :, p]
        colq_re, colq_im = g_re[:, :, q], g_im[:, :, q]
        mq_re, mq_im = _cmul(phm[0], phm[1], colq_re, colq_im)
        mp_re, mp_im = _cmul(php[0], php[1], colp_re, colp_im)
        new_p_re = cb * colp_re - sb * mq_re
        new_p_im = cb * colp_im - sb * mq_im
        new_q_re = sb * mp_re + cb * colq_re
        new_q_im = sb * mp_im + cb * colq_im
        g_re = g_re.at[:, :, p].set(new_p_re).at[:, :, q].set(new_q_re)
        g_im = g_im.at[:, :, p].set(new_p_im).at[:, :, q].set(new_q_im)

        # Left-multiply by R2^H:
        #   row_p' = c row_p - s e^{+i phi} row_q
        #   row_q' = s e^{-i phi} row_p + c row_q
        rowp_re, rowp_im = g_re[:, p, :], g_im[:, p, :]
        rowq_re, rowq_im = g_re[:, q, :], g_im[:, q, :]
        mq_re, mq_im = _cmul(php[0], php[1], rowq_re, rowq_im)
        mp_re, mp_im = _cmul(phm[0], phm[1], rowp_re, rowp_im)
        new_p_re = cb * rowp_re - sb * mq_re
        new_p_im = cb * rowp_im - sb * mq_im
        new_q_re = sb * mp_re + cb * rowq_re
        new_q_im = sb * mp_im + cb * rowq_im
        g_re = g_re.at[:, p, :].set(new_p_re).at[:, q, :].set(new_q_re)
        g_im = g_im.at[:, p, :].set(new_p_im).at[:, q, :].set(new_q_im)
        return g_re, g_im

    g_re, g_im = jax.lax.fori_loop(0, sweeps * npairs, rotate, (g_re, g_im))
    lam = jnp.sum(g_re * jnp.eye(r, dtype=g_re.dtype)[None], axis=2)
    return -jnp.sort(-lam, axis=-1)


def spectrum_fn(cfg: SpectrumConfig, interpret=True):
    """Build the traced pipeline for a config. Returns ``f(w, row_offset)``
    mapping OIHW weights + tile row offset to ``(sv [F, r],)``."""

    def fn(w, row_offset):
        t = cfg.kh * cfg.kw
        p_re, p_im = traced_phases(cfg, row_offset)
        w_flat = w.reshape(cfg.c_out * cfg.c_in, t).astype(jnp.float32)
        b_re, b_im = lfa_symbol(p_re, p_im, w_flat, interpret=interpret)
        b_re = b_re.reshape(cfg.freqs, cfg.c_out, cfg.c_in)
        b_im = b_im.reshape(cfg.freqs, cfg.c_out, cfg.c_in)
        if cfg.c_out < cfg.c_in:
            # Use the smaller Gram side: G = B B^H = (B^H)^H (B^H) with
            # B^H carried as (re^T, -im^T).
            b_re = jnp.swapaxes(b_re, 1, 2)
            b_im = -jnp.swapaxes(b_im, 1, 2)
        g_re, g_im = gram(b_re, b_im, interpret=interpret)
        lam = jacobi_eigvals(g_re, g_im, cfg.sweeps)
        sv = jnp.sqrt(jnp.maximum(lam, 0.0))
        return (sv,)

    return fn


@functools.partial(jax.jit, static_argnames=("cfg", "interpret"))
def spectrum(w, row_offset, cfg: SpectrumConfig, interpret=True):
    """Jitted convenience wrapper used by the pytest suite."""
    return spectrum_fn(cfg, interpret=interpret)(w, row_offset)[0]
