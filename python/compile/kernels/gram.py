"""Layer-1 Pallas kernel: batched Hermitian Gram matrices.

Given per-frequency symbols ``B_k`` (``c_out x c_in``, complex as re/im
planes), compute ``G_k = B_k^H B_k`` (or ``B_k B_k^H`` when ``c_out < c_in``
— the smaller Gram side).  ``G_k`` is Hermitian PSD with ``sigma(B_k) =
sqrt(lambda(G_k))``; the L2 model feeds it to the pure-HLO Jacobi
eigensolver.

Complex expansion with real matmuls (weights of the MXU):
  Re(G) = Br^T Br + Bi^T Bi
  Im(G) = Br^T Bi - Bi^T Br

The frequency axis is the batch; each grid step processes ``TILE_B``
frequencies with all four small matmuls fused in VMEM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_B = 64


def _gram_kernel(b_re_ref, b_im_ref, g_re_ref, g_im_ref):
    br = b_re_ref[...]  # [TB, co, ci]
    bi = b_im_ref[...]
    # Batched B^H B via dot_general over the batch dim.
    dn = (((1,), (1,)), ((0,), (0,)))  # contract co, batch TB
    rr = jax.lax.dot_general(br, br, dn, preferred_element_type=jnp.float32)
    ii = jax.lax.dot_general(bi, bi, dn, preferred_element_type=jnp.float32)
    ri = jax.lax.dot_general(br, bi, dn, preferred_element_type=jnp.float32)
    ir = jax.lax.dot_general(bi, br, dn, preferred_element_type=jnp.float32)
    g_re_ref[...] = rr + ii
    g_im_ref[...] = ri - ir


@functools.partial(jax.jit, static_argnames=("interpret", "tile_b"))
def gram(b_re, b_im, *, interpret=True, tile_b=TILE_B):
    """Batched Hermitian Gram ``G = B^H B``.

    Args:
      b_re, b_im: ``[F, c_out, c_in]`` symbol planes.

    Returns:
      ``(g_re, g_im)`` of shape ``[F, c_in, c_in]``.
    """
    f, co, ci = b_re.shape
    tile = min(tile_b, f)
    f_pad = -(-f // tile) * tile
    if f_pad != f:
        pad = ((0, f_pad - f), (0, 0), (0, 0))
        b_re = jnp.pad(b_re, pad)
        b_im = jnp.pad(b_im, pad)
    grid = (f_pad // tile,)
    out_shape = [
        jax.ShapeDtypeStruct((f_pad, ci, ci), jnp.float32),
        jax.ShapeDtypeStruct((f_pad, ci, ci), jnp.float32),
    ]
    g_re, g_im = pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, co, ci), lambda i: (i, 0, 0)),
            pl.BlockSpec((tile, co, ci), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile, ci, ci), lambda i: (i, 0, 0)),
            pl.BlockSpec((tile, ci, ci), lambda i: (i, 0, 0)),
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(b_re, b_im)
    return g_re[:f], g_im[:f]
