"""Layer-1 Pallas kernel: LFA symbol construction.

The symbol of a convolution at frequency ``k`` is
``A_k = sum_y M_y e^{2 pi i <k, y>}``.  Stacking all ``F = n*m`` frequencies
and flattening the taps, this is a single real-valued contraction

    B[f, p] = sum_t P[f, t] * W[p, t]        (p = o*c_in + i, t = tap index)

split into real/imaginary planes (the CPU PJRT plugin is happiest with f32,
and on TPU this shape feeds the MXU directly: an ``F x T`` by ``T x C``
matmul tiled along ``F``).

Hardware adaptation (DESIGN.md section Hardware-Adaptation): the paper runs
on CPU/NumPy; here the frequency grid is tiled via ``BlockSpec`` so each
grid step holds ``TILE_F x T`` phases + the full ``C x T`` weight panel in
VMEM, and the contraction is MXU-shaped.  ``interpret=True`` everywhere on
CPU (Mosaic custom-calls cannot run on the CPU plugin).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default frequency-tile height. 128 rows x (T<=25 taps) x 4 bytes is tiny;
# the tile is sized so that B-tiles (TILE_F x C) stay well under VMEM even
# for c=64 (128*4096*4 = 2 MiB/plane).
TILE_F = 128


def _symbol_kernel(p_re_ref, p_im_ref, w_ref, b_re_ref, b_im_ref):
    """One frequency tile: B_tile = P_tile @ W^T (re and im planes)."""
    p_re = p_re_ref[...]
    p_im = p_im_ref[...]
    w = w_ref[...]  # [C, T]
    # Real contraction twice: weights are real, so re/im separate cleanly.
    b_re_ref[...] = jnp.dot(p_re, w.T, preferred_element_type=jnp.float32)
    b_im_ref[...] = jnp.dot(p_im, w.T, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret", "tile_f"))
def lfa_symbol(p_re, p_im, w_flat, *, interpret=True, tile_f=TILE_F):
    """Compute symbol planes.

    Args:
      p_re, p_im: ``[F, T]`` phase tables ``e^{2 pi i <k, y_t>}`` split into
        real/imag parts.
      w_flat: ``[C, T]`` weight tensor flattened to (c_out*c_in, taps).
      interpret: run the Pallas kernel in interpret mode (required on CPU).
      tile_f: frequency-tile height (static).

    Returns:
      ``(b_re, b_im)`` of shape ``[F, C]``.
    """
    f, t = p_re.shape
    c = w_flat.shape[0]
    assert w_flat.shape[1] == t, (w_flat.shape, t)
    tile = min(tile_f, f)
    # Pad F to a multiple of the tile so the grid divides evenly.
    f_pad = -(-f // tile) * tile
    if f_pad != f:
        pad = ((0, f_pad - f), (0, 0))
        p_re = jnp.pad(p_re, pad)
        p_im = jnp.pad(p_im, pad)
    grid = (f_pad // tile,)
    out_shape = [
        jax.ShapeDtypeStruct((f_pad, c), jnp.float32),
        jax.ShapeDtypeStruct((f_pad, c), jnp.float32),
    ]
    b_re, b_im = pl.pallas_call(
        _symbol_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, t), lambda i: (i, 0)),
            pl.BlockSpec((tile, t), lambda i: (i, 0)),
            pl.BlockSpec((c, t), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile, c), lambda i: (i, 0)),
            pl.BlockSpec((tile, c), lambda i: (i, 0)),
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(p_re, p_im, w_flat)
    return b_re[:f], b_im[:f]
