"""Pure-jnp/numpy correctness oracles for the Pallas kernels and L2 pipeline.

Everything here may use ``np.linalg`` freely: references run only at build
time under pytest, never inside an AOT artifact (jax>=0.5 lowers linalg to
``lapack_*_ffi`` custom calls that xla_extension 0.5.1 cannot execute).
"""

import numpy as np
import jax.numpy as jnp


def phase_matrix(n, m, kh, kw, anchor=None, row_offset=0, rows=None):
    """``[rows*m, kh*kw]`` complex phase table ``e^{2 pi i <k, y_t>}``.

    Frequencies are ``k = (i/n, j/m)`` for grid rows ``i`` in
    ``[row_offset, row_offset+rows)`` and all ``j``; taps are row-major with
    displacements relative to ``anchor`` (default: centered).
    """
    if anchor is None:
        anchor = (kh // 2, kw // 2)
    if rows is None:
        rows = n
    ar, ac = anchor
    ii = np.arange(row_offset, row_offset + rows)
    jj = np.arange(m)
    dy = np.arange(kh) - ar
    dx = np.arange(kw) - ac
    # [rows, kh] and [m, kw] separable phases
    py = np.exp(2j * np.pi * np.outer(ii, dy) / n)
    px = np.exp(2j * np.pi * np.outer(jj, dx) / m)
    # combine: [rows, m, kh, kw] -> [rows*m, kh*kw]
    p = py[:, None, :, None] * px[None, :, None, :]
    return p.reshape(rows * m, kh * kw)


def symbol_ref(w, n, m, row_offset=0, rows=None):
    """Reference symbols ``[F, c_out, c_in]`` (complex) for OIHW weights."""
    c_out, c_in, kh, kw = w.shape
    p = phase_matrix(n, m, kh, kw, row_offset=row_offset, rows=rows)
    w_flat = np.asarray(w).reshape(c_out * c_in, kh * kw)
    b = p @ w_flat.T  # [F, C]
    return b.reshape(p.shape[0], c_out, c_in)


def gram_ref(b):
    """Reference Gram ``B^H B`` for ``[F, c_out, c_in]`` complex symbols."""
    return np.einsum("foi,foj->fij", np.conj(b), b)


def singular_values_ref(w, n, m):
    """Reference spectrum via numpy SVD of the symbols: ``[F, r]`` desc."""
    b = symbol_ref(w, n, m)
    return np.linalg.svd(b, compute_uv=False)  # numpy returns descending


def singular_values_explicit(w, n, m, periodic=True):
    """Ground truth from the explicit unrolled matrix (small sizes only)."""
    c_out, c_in, kh, kw = w.shape
    ar, ac = kh // 2, kw // 2
    a = np.zeros((n * m * c_out, n * m * c_in))
    for xr in range(n):
        for xc in range(m):
            for r in range(kh):
                for c in range(kw):
                    sr, sc = xr + r - ar, xc + c - ac
                    if periodic:
                        sr, sc = sr % n, sc % m
                    elif not (0 <= sr < n and 0 <= sc < m):
                        continue
                    dst = xr * m + xc
                    src = sr * m + sc
                    a[dst * c_out:(dst + 1) * c_out,
                      src * c_in:(src + 1) * c_in] += w[:, :, r, c]
    return np.linalg.svd(a, compute_uv=False)


def jacobi_eigvals_ref(g):
    """Reference eigenvalues (descending) of batched Hermitian ``g``."""
    return np.linalg.eigvalsh(g)[..., ::-1]


def as_f32(x):
    return jnp.asarray(x, dtype=jnp.float32)
