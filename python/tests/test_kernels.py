"""Pallas kernels vs pure-numpy oracles (the core L1 correctness signal)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.gram import gram
from compile.kernels.lfa_symbol import lfa_symbol


def rand_weights(rng, c_out, c_in, kh=3, kw=3):
    return rng.standard_normal((c_out, c_in, kh, kw)).astype(np.float32)


@pytest.mark.parametrize("n,m,c_out,c_in", [(4, 4, 2, 2), (8, 8, 4, 4), (8, 6, 3, 5), (16, 16, 8, 8)])
def test_symbol_kernel_matches_ref(n, m, c_out, c_in):
    rng = np.random.default_rng(0)
    w = rand_weights(rng, c_out, c_in)
    p = ref.phase_matrix(n, m, 3, 3)
    b_re, b_im = lfa_symbol(
        ref.as_f32(p.real), ref.as_f32(p.imag), ref.as_f32(w.reshape(c_out * c_in, 9))
    )
    want = ref.symbol_ref(w, n, m).reshape(n * m, c_out * c_in)
    np.testing.assert_allclose(np.asarray(b_re), want.real, atol=2e-5)
    np.testing.assert_allclose(np.asarray(b_im), want.imag, atol=2e-5)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(2, 12),
    m=st.integers(2, 12),
    c_out=st.integers(1, 6),
    c_in=st.integers(1, 6),
    kh=st.sampled_from([1, 3, 5]),
    kw=st.sampled_from([1, 3, 5]),
    seed=st.integers(0, 2**31 - 1),
)
def test_symbol_kernel_hypothesis(n, m, c_out, c_in, kh, kw, seed):
    """Shape/dtype sweep: pallas symbol == oracle for arbitrary configs."""
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((c_out, c_in, kh, kw)).astype(np.float32)
    p = ref.phase_matrix(n, m, kh, kw)
    b_re, b_im = lfa_symbol(
        ref.as_f32(p.real), ref.as_f32(p.imag), ref.as_f32(w.reshape(c_out * c_in, kh * kw))
    )
    want = ref.symbol_ref(w, n, m).reshape(n * m, c_out * c_in)
    scale = max(1.0, np.abs(want).max())
    np.testing.assert_allclose(np.asarray(b_re), want.real, atol=3e-5 * scale)
    np.testing.assert_allclose(np.asarray(b_im), want.imag, atol=3e-5 * scale)


@pytest.mark.parametrize("f,c_out,c_in", [(16, 4, 4), (64, 8, 8), (10, 3, 5), (100, 5, 3)])
def test_gram_kernel_matches_ref(f, c_out, c_in):
    rng = np.random.default_rng(1)
    b = rng.standard_normal((f, c_out, c_in)) + 1j * rng.standard_normal((f, c_out, c_in))
    b = b.astype(np.complex64)
    g_re, g_im = gram(ref.as_f32(b.real), ref.as_f32(b.imag))
    want = ref.gram_ref(b)
    np.testing.assert_allclose(np.asarray(g_re), want.real, atol=1e-4)
    np.testing.assert_allclose(np.asarray(g_im), want.imag, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    f=st.integers(1, 130),
    c_out=st.integers(1, 8),
    c_in=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_gram_kernel_hypothesis(f, c_out, c_in, seed):
    rng = np.random.default_rng(seed)
    b = rng.standard_normal((f, c_out, c_in)) + 1j * rng.standard_normal((f, c_out, c_in))
    g_re, g_im = gram(ref.as_f32(b.real), ref.as_f32(b.imag))
    want = ref.gram_ref(b.astype(np.complex64))
    scale = max(1.0, np.abs(want).max())
    np.testing.assert_allclose(np.asarray(g_re), want.real, atol=2e-5 * scale)
    np.testing.assert_allclose(np.asarray(g_im), want.imag, atol=2e-5 * scale)


def test_gram_is_hermitian_psd():
    rng = np.random.default_rng(2)
    b = rng.standard_normal((32, 6, 6)) + 1j * rng.standard_normal((32, 6, 6))
    g_re, g_im = gram(ref.as_f32(b.real), ref.as_f32(b.imag))
    g = np.asarray(g_re) + 1j * np.asarray(g_im)
    np.testing.assert_allclose(g, np.conj(np.swapaxes(g, 1, 2)), atol=1e-5)
    evals = np.linalg.eigvalsh(g)
    assert (evals > -1e-4).all()


def test_phase_matrix_tiling():
    """Tiled phase tables stitch to the full table."""
    full = ref.phase_matrix(8, 6, 3, 3)
    t0 = ref.phase_matrix(8, 6, 3, 3, row_offset=0, rows=3)
    t1 = ref.phase_matrix(8, 6, 3, 3, row_offset=3, rows=5)
    np.testing.assert_allclose(np.vstack([t0, t1]), full)
