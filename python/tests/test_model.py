"""L2 pipeline tests: pure-HLO Jacobi vs numpy, end-to-end spectrum vs
oracle and vs the explicit unrolled matrix (small sizes)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.model import SpectrumConfig, jacobi_eigvals, spectrum


def rand_hermitian(rng, f, r):
    a = rng.standard_normal((f, r, r)) + 1j * rng.standard_normal((f, r, r))
    return (a + np.conj(np.swapaxes(a, 1, 2))) * 0.5


@pytest.mark.parametrize("f,r", [(4, 2), (16, 4), (8, 8), (3, 1)])
def test_jacobi_eigvals_match_numpy(f, r):
    rng = np.random.default_rng(10)
    g = rand_hermitian(rng, f, r)
    got = np.asarray(
        jacobi_eigvals(ref.as_f32(g.real), ref.as_f32(g.imag), sweeps=12)
    )
    want = ref.jacobi_eigvals_ref(g)
    scale = max(1.0, np.abs(want).max())
    np.testing.assert_allclose(got, want, atol=5e-5 * scale)


@settings(max_examples=15, deadline=None)
@given(f=st.integers(1, 40), r=st.integers(1, 10), seed=st.integers(0, 2**31 - 1))
def test_jacobi_eigvals_hypothesis(f, r, seed):
    rng = np.random.default_rng(seed)
    g = rand_hermitian(rng, f, r)
    got = np.asarray(jacobi_eigvals(ref.as_f32(g.real), ref.as_f32(g.imag), sweeps=14))
    want = ref.jacobi_eigvals_ref(g)
    scale = max(1.0, np.abs(want).max())
    np.testing.assert_allclose(got, want, atol=1e-4 * scale)


@pytest.mark.parametrize(
    "cfg",
    [
        SpectrumConfig(n=4, m=4, c_out=2, c_in=2),
        SpectrumConfig(n=8, m=8, c_out=4, c_in=4),
        SpectrumConfig(n=8, m=6, c_out=3, c_in=5),
        SpectrumConfig(n=8, m=6, c_out=5, c_in=3),
    ],
)
def test_spectrum_matches_oracle(cfg):
    rng = np.random.default_rng(11)
    w = rng.standard_normal((cfg.c_out, cfg.c_in, cfg.kh, cfg.kw)).astype(np.float32)
    got = np.asarray(spectrum(jnp.asarray(w), jnp.int32(0), cfg))
    want = ref.singular_values_ref(w, cfg.n, cfg.m)
    scale = max(1.0, want.max())
    np.testing.assert_allclose(got, want, atol=2e-4 * scale)


def test_spectrum_matches_explicit_matrix():
    """Full pipeline vs ground-truth unrolled periodic matrix."""
    cfg = SpectrumConfig(n=4, m=4, c_out=3, c_in=3)
    rng = np.random.default_rng(12)
    w = rng.standard_normal((3, 3, 3, 3)).astype(np.float32)
    got = np.sort(np.asarray(spectrum(jnp.asarray(w), jnp.int32(0), cfg)).ravel())[::-1]
    want = ref.singular_values_explicit(w, 4, 4, periodic=True)
    np.testing.assert_allclose(got, want, atol=3e-4 * max(1.0, want.max()))


def test_tiled_spectrum_stitches_to_full():
    """Tiled artifact semantics: runs over row tiles == full grid run."""
    full_cfg = SpectrumConfig(n=8, m=8, c_out=4, c_in=4)
    tile_cfg = SpectrumConfig(n=8, m=8, c_out=4, c_in=4, tile_rows=2)
    rng = np.random.default_rng(13)
    w = jnp.asarray(rng.standard_normal((4, 4, 3, 3)).astype(np.float32))
    full = np.asarray(spectrum(w, jnp.int32(0), full_cfg))
    tiles = [np.asarray(spectrum(w, jnp.int32(off), tile_cfg)) for off in range(0, 8, 2)]
    np.testing.assert_allclose(np.vstack(tiles), full, atol=1e-5)


def test_identity_kernel_spectrum_is_ones():
    cfg = SpectrumConfig(n=4, m=4, c_out=2, c_in=2)
    w = np.zeros((2, 2, 3, 3), dtype=np.float32)
    w[0, 0, 1, 1] = 1.0
    w[1, 1, 1, 1] = 1.0
    got = np.asarray(spectrum(jnp.asarray(w), jnp.int32(0), cfg))
    np.testing.assert_allclose(got, np.ones_like(got), atol=1e-5)


def test_frobenius_identity():
    """sum sigma^2 == n*m*||W||_F^2 (periodic)."""
    cfg = SpectrumConfig(n=8, m=8, c_out=4, c_in=4)
    rng = np.random.default_rng(14)
    w = rng.standard_normal((4, 4, 3, 3)).astype(np.float32)
    sv = np.asarray(spectrum(jnp.asarray(w), jnp.int32(0), cfg))
    lhs = float((sv**2).sum())
    rhs = 64.0 * float((w**2).sum())
    assert abs(lhs - rhs) / rhs < 1e-4
