"""AOT artifact regression tests.

Guards the two interchange constraints that cost real debugging time (see
EXPERIMENTS.md section Perf / artifact-format findings):
  1. artifacts must not contain elided constants ("{...}" placeholders) --
     xla_extension 0.5.1's parser silently reads them as ZEROS;
  2. artifacts must not contain jaxlib FFI custom-calls (lapack_*_ffi,
     ducc_fft) -- unexecutable on the rust runtime;
  3. metadata attributes must be stripped (the old parser rejects
     source_end_line).
"""

import re

import pytest

from compile.aot import lower_config, CONFIGS
from compile.model import SpectrumConfig


@pytest.fixture(scope="module")
def small_artifact():
    return lower_config(SpectrumConfig(n=8, m=8, c_out=4, c_in=4))


def test_no_elided_constants(small_artifact):
    assert "{...}" not in small_artifact, (
        "HLO printer elided a large constant; xla_extension 0.5.1 parses it "
        "as zeros. to_hlo_text must set print_large_constants=True."
    )


def test_no_ffi_custom_calls(small_artifact):
    for pattern in ("custom-call", "lapack", "ducc"):
        assert pattern not in small_artifact.lower(), (
            f"artifact contains {pattern!r}: jnp.linalg/jnp.fft leaked into "
            "the lowered pipeline"
        )


def test_no_metadata_attributes(small_artifact):
    assert "source_end_line" not in small_artifact
    assert "metadata=" not in small_artifact


def test_artifact_is_parseable_hlo(small_artifact):
    # Structural sanity: an entry computation with our parameter signature.
    assert small_artifact.startswith("HloModule")
    assert re.search(r"ENTRY\s", small_artifact)
    assert "f32[4,4,3,3]" in small_artifact, "weights parameter"
    assert "s32[]" in small_artifact, "row_offset parameter"


def test_all_configs_have_unique_names():
    names = [c.name for c in CONFIGS]
    assert len(names) == len(set(names))


def test_tiled_config_shapes():
    tiled = [c for c in CONFIGS if c.tile_rows]
    assert tiled, "manifest should include tiled artifacts for the scheduler"
    for c in tiled:
        assert c.n % c.tile_rows == 0, f"{c.name}: tile must divide grid"
